"""Config/arch registry protocol + step builders for the dry-run.

Every architecture module exposes an ``ArchSpec``:

    name        arch id (``--arch`` value)
    family      lm | gnn | recsys | dc
    full        full-scale config (public-literature numbers)
    smoke       reduced config for CPU smoke tests
    shapes      {shape_name: ShapeDef} — the assigned input-shape set
    build_cell  (cfg, shape, mesh) → Cell with the jittable fn, example-input
                ShapeDtypeStructs, and in/out shardings

Cells are lowered with ``jax.jit(fn, in_shardings=…).lower(*structs)``; no
real arrays are ever allocated for the full configs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.runtime import mesh_rules

Struct = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    kind: str  # train | prefill | decode | serve | retrieval
    meta: dict


@dataclasses.dataclass
class Cell:
    """One (arch × shape) dry-run unit."""

    name: str
    fn: Callable
    args: tuple  # ShapeDtypeStructs (or real arrays for smoke)
    in_shardings: Any
    out_shardings: Any = None
    static_argnums: tuple = ()
    model_flops: float = 0.0  # 6·N·D (dense) / 6·N_active·D (MoE); 0 = n/a
    mesh: Any = None  # set by build_cell; activates logical-axis constraints

    def lower(self):
        from repro.models.common import activation_mesh

        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
        )
        with activation_mesh(self.mesh):
            return jitted.lower(*self.args)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str
    full: Callable[[], Any]
    smoke: Callable[[], Any]
    shapes: dict
    build_cell: Callable[[Any, str, Mesh], Cell]
    notes: str = ""


def named(mesh: Mesh, *axes) -> NamedSharding:
    return NamedSharding(mesh, mesh_rules.logical_to_spec(axes, mesh))


def batch_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, mesh_rules.shard_batch_spec(mesh))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def tree_struct(fn, *args, **kw):
    """eval_shape → ShapeDtypeStruct tree (no allocation)."""
    return jax.eval_shape(fn, *args, **kw)
