"""mind [recsys]: embed_dim=64, 4 interests, 3 capsule iterations,
multi-interest interaction. [arXiv:1904.08030; unverified]

Shapes: train_batch (B=65,536 sampled-softmax training), serve_p99 (B=512
online scoring), serve_bulk (B=262,144 offline scoring), retrieval_cand
(1 query × 1,000,000 candidates — single batched-dot matmul).
"""

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.common import ArchSpec, Cell, ShapeDef, Struct, replicated, tree_struct
from repro.models.recsys import mind as model
from repro.optim import adamw_init, adamw_update
from repro.runtime import mesh_rules

SHAPES = {
    "train_batch": ShapeDef("train", dict(batch=65536)),
    "serve_p99": ShapeDef("serve", dict(batch=512, candidates=1024)),
    "serve_bulk": ShapeDef("serve", dict(batch=262144, candidates=128)),
    "retrieval_cand": ShapeDef("retrieval", dict(batch=1, candidates=1_000_000)),
}


def full() -> model.MINDConfig:
    return model.MINDConfig(
        num_items=8_388_608, embed_dim=64, n_interests=4, capsule_iters=3, seq_len=50
    )


def smoke() -> model.MINDConfig:
    return model.MINDConfig(num_items=512, embed_dim=16, seq_len=8, hidden=32)


def _shardings(cfg, mesh):
    table = NamedSharding(mesh, mesh_rules.logical_to_spec(("table_rows", None), mesh))
    rep = replicated(mesh)
    return {
        "item_table": table,
        "bilinear_s": rep,
        "mlp_w1": rep,
        "mlp_b1": rep,
        "mlp_w2": rep,
        "mlp_b2": rep,
    }


def build_cell(cfg, shape_name, mesh):
    from repro.configs.common import batch_sharding

    meta = SHAPES[shape_name].meta
    b = meta["batch"]
    d, L, K = cfg.embed_dim, cfg.seq_len, cfg.n_interests
    # useful matmul flops: bilinear map + routing agreements + interest MLP
    fwd_interests = b * (L * 2 * d * d
                         + cfg.capsule_iters * 2 * K * L * 2 * d
                         + K * (2 * d * cfg.hidden + 2 * cfg.hidden * d))
    ps = tree_struct(lambda: model.init_params(cfg, jax.random.PRNGKey(0)))
    psh = _shardings(cfg, mesh)
    bsh = batch_sharding(mesh)
    rep = replicated(mesh)
    kind = SHAPES[shape_name].kind

    if kind == "train":
        def train_step(params, opt_state, behavior, valid, target, neg):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss_fn(cfg, p, behavior, valid, target, neg)
            )(params)
            new_p, new_o, gnorm = adamw_update(params, grads, opt_state, lr=1e-3)
            return new_p, new_o, {"loss": loss, "gnorm": gnorm}

        os_ = tree_struct(adamw_init, ps)
        osh = jax.tree.map(lambda _: rep, os_)
        osh = osh._replace(mu=psh, nu=psh)
        args = (
            ps, os_,
            Struct((b, cfg.seq_len), jnp.int32),
            Struct((b, cfg.seq_len), jnp.bool_),
            Struct((b,), jnp.int32),
            Struct((b, 20), jnp.int32),
        )
        in_sh = (psh, osh, bsh, bsh, bsh, bsh)
        mf = 3.0 * (fwd_interests + b * 21 * 2 * d)  # + sampled softmax
        return Cell(f"mind:{shape_name}", train_step, args, in_sh, mesh=mesh,
                    model_flops=mf)

    c = meta["candidates"]
    if kind == "serve":
        def serve_step(params, behavior, valid, candidates):
            return model.serve_scores(cfg, params, behavior, valid, candidates)

        args = (
            ps,
            Struct((b, cfg.seq_len), jnp.int32),
            Struct((b, cfg.seq_len), jnp.bool_),
            Struct((b, c), jnp.int32),
        )
        in_sh = (psh, bsh, bsh, bsh)
        mf = fwd_interests + b * K * c * 2 * d
        return Cell(f"mind:{shape_name}", serve_step, args, in_sh, mesh=mesh,
                    model_flops=mf)

    # retrieval: candidate slab sharded over the model axis (batched dot)
    def retrieval_step(params, behavior, valid, candidates):
        return model.retrieval_scores(cfg, params, behavior, valid, candidates)

    cand_sh = NamedSharding(mesh, mesh_rules.logical_to_spec(("table_rows",), mesh))
    args = (
        ps,
        Struct((b, cfg.seq_len), jnp.int32),
        Struct((b, cfg.seq_len), jnp.bool_),
        Struct((c,), jnp.int32),
    )
    in_sh = (psh, rep, rep, cand_sh)
    mf = fwd_interests + b * K * c * 2 * d
    return Cell(f"mind:{shape_name}", retrieval_step, args, in_sh, mesh=mesh,
                model_flops=mf)


ARCH = ArchSpec(
    name="mind", family="recsys", full=full, smoke=smoke,
    shapes=SHAPES, build_cell=build_cell,
    notes="EmbeddingBag = take + segment_sum (no native JAX EmbeddingBag); "
    "table rows sharded over the model axis.",
)
