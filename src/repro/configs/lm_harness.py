"""Shared cell builders for the LM-family architectures.

Shapes (assigned): train_4k (train_step), prefill_32k (prefill), decode_32k
(serve_step: 1 new token against a seq_len KV cache).  long_500k is skipped
for these archs — all five are full-softmax attention (GQA/MLA included);
see DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.common import (
    Cell,
    ShapeDef,
    Struct,
    batch_sharding,
    replicated,
    tree_struct,
)
from repro.models import transformer as tf
from repro.optim import adamw_init, adamw_update
from repro.runtime import mesh_rules

LM_SHAPES = {
    "train_4k": ShapeDef("train", dict(seq_len=4096, global_batch=256)),
    "prefill_32k": ShapeDef("prefill", dict(seq_len=32768, global_batch=32)),
    "decode_32k": ShapeDef("decode", dict(seq_len=32768, global_batch=128)),
    # long_500k: skipped — pure full-attention archs (documented in DESIGN.md)
}


def param_structs(cfg: tf.TransformerConfig):
    return tree_struct(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))


def param_shardings(cfg: tf.TransformerConfig, mesh: Mesh):
    specs = tf.param_specs(cfg)
    return mesh_rules.shardings_for(specs, mesh)


def opt_structs(cfg: tf.TransformerConfig):
    ps = param_structs(cfg)
    return tree_struct(adamw_init, ps)


def opt_shardings(cfg: tf.TransformerConfig, mesh: Mesh):
    from repro.optim.adamw import AdamWState

    psh = param_shardings(cfg, mesh)
    return AdamWState(step=replicated(mesh), mu=psh, nu=psh)


def make_train_step(cfg: tf.TransformerConfig, grad_accum: int = 1):
    """grad_accum > 1 splits the batch into microbatches scanned
    sequentially, accumulating grads — activation memory scales 1/accum at
    identical math (the optimizer sees the mean gradient)."""

    def train_step(params, opt_state, tokens, labels):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(
                lambda p: tf.loss_fn(cfg, p, tokens, labels)
            )(params)
        else:
            b = tokens.shape[0]
            assert b % grad_accum == 0
            mb = b // grad_accum
            tok = tokens.reshape(grad_accum, mb, -1)
            lab = labels.reshape(grad_accum, mb, -1)

            def micro(carry, xs):
                acc, loss_acc = carry
                t, l = xs
                loss, g = jax.value_and_grad(
                    lambda p: tf.loss_fn(cfg, p, t, l)
                )(params)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), (tok, lab))
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
        new_params, new_opt, gnorm = adamw_update(params, grads, opt_state, lr=3e-4)
        return new_params, new_opt, {"loss": loss, "gnorm": gnorm}

    return train_step


def make_prefill(cfg: tf.TransformerConfig):
    def prefill(params, tokens):
        logits, cache, _ = tf.forward(cfg, params, tokens)
        return logits[:, -1], cache

    return prefill


def make_decode(cfg: tf.TransformerConfig):
    def serve_step(params, cache, tokens, pos):
        return tf.decode_step(cfg, params, cache, tokens, pos)

    return serve_step


def build_lm_cell(
    cfg: tf.TransformerConfig, shape_name: str, mesh: Mesh,
    force_accum: int | None = None,
) -> Cell:
    shape = LM_SHAPES[shape_name]
    meta = shape.meta
    b, s = meta["global_batch"], meta["seq_len"]
    ps = param_structs(cfg)
    psh = param_shardings(cfg, mesh)
    bsh = batch_sharding(mesh)
    model_flops = 6.0 * cfg.num_active_params() * b * s

    if shape.kind == "train":
        # §Perf: microbatch counts chosen so the step fits 16 GB v5e HBM.
        # force_accum=1 is used by the dry-run's cost extrapolation (the
        # accumulate-scan body would be counted once by cost_analysis).
        accum = {
            "qwen2-72b": 16,
            "arctic-480b": 32,  # MoE dispatch buffers dominate → deeper split
            "minicpm3-4b": 8,  # 62 layers of saved residuals
            "qwen2-moe-a2.7b": 8,
        }.get(cfg.name, 1)
        fn = make_train_step(cfg, grad_accum=force_accum or accum)
        args = (
            ps,
            opt_structs(cfg),
            Struct((b, s), jnp.int32),
            Struct((b, s), jnp.int32),
        )
        in_sh = (psh, opt_shardings(cfg, mesh), bsh, bsh)
        return Cell(f"{cfg.name}:{shape_name}", fn, args, in_sh, model_flops=model_flops, mesh=mesh)

    if shape.kind == "prefill":
        fn = make_prefill(cfg)
        args = (ps, Struct((b, s), jnp.int32))
        in_sh = (psh, bsh)
        return Cell(f"{cfg.name}:{shape_name}", fn, args, in_sh, model_flops=model_flops, mesh=mesh)

    if shape.kind == "decode":
        fn = make_decode(cfg)
        cache_structs = tree_struct(lambda: tf.init_cache(cfg, b, s))
        cache_sh = mesh_rules.shardings_for(
            tf.cache_specs(cfg), mesh
        )
        args = (ps, cache_structs, Struct((b,), jnp.int32), Struct((b,), jnp.int32))
        in_sh = (psh, cache_sh, bsh, bsh)
        # decode model flops: one token per sequence
        return Cell(
            f"{cfg.name}:{shape_name}", fn, args, in_sh,
            model_flops=6.0 * cfg.num_active_params() * b, mesh=mesh)

    raise ValueError(shape.kind)
