"""llama3.2-1b [dense]: 16L, d=2048, 32H (GQA kv=8), d_ff=8192, vocab=128256.
[hf:meta-llama/Llama-3.2-1B; unverified]"""

import jax.numpy as jnp

from repro.configs.common import ArchSpec
from repro.configs.lm_harness import LM_SHAPES, build_lm_cell
from repro.models.transformer import TransformerConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="llama3.2-1b",
        num_layers=16,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128256,
        attention="gqa",
        rope_theta=5e5,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="llama3.2-1b-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attention="gqa",
        dtype=jnp.float32,
        attn_block_q=16,
        attn_block_k=16,
    )


ARCH = ArchSpec(
    name="llama3.2-1b",
    family="lm",
    full=full,
    smoke=smoke,
    shapes=LM_SHAPES,
    build_cell=build_lm_cell,
    notes="long_500k skipped: full-softmax attention.",
)
