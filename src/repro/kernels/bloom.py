"""Multi-probe Bloom-filter query over packed u32 words (Prob-Drop, §5.1.2).

The production layout is the packed bit array (M/32 u32 words — this is the
size the memory accountant charges).  One kernel invocation answers a tile
of (vertex, iteration) keys: k double-hashed probes per key, each a VMEM
word gather + bit test, combined with a running AND.  Compared to the
pure-JAX boolean-array fallback this avoids materializing [N, k] probe
tensors in HBM and keeps the whole filter row resident in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.interpret import resolve_interpret

# numpy scalars embed as literals in the kernel (device constants would be
# rejected as captured consts by pallas_call)
_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_C3 = np.uint32(0x27D4EB2F)


def _mix(x):
    x = x.astype(jnp.uint32)
    x ^= x >> 16
    x *= _C1
    x ^= x >> 13
    x *= _C2
    x ^= x >> 16
    return x


def hash_pair(v, i, salt):
    v = v.astype(jnp.uint32)
    i = i.astype(jnp.uint32)
    s = jnp.asarray(salt, jnp.uint32)
    h1 = _mix(v * _C3 ^ _mix(i + s))
    h2 = _mix(i * _C1 ^ _mix(v ^ (s * _C2))) | jnp.uint32(1)
    return h1, h2


def _kernel(words_ref, v_ref, i_ref, salt_ref, out_ref, *, num_hashes, num_bits):
    words = words_ref[0, :]  # [M/32] u32, VMEM resident
    v = v_ref[0, :]
    it = i_ref[0, :]
    salt = salt_ref[0]
    h1, h2 = hash_pair(v, it, salt)
    hit = jnp.ones(v.shape, dtype=jnp.bool_)
    for j in range(num_hashes):  # k is small & static → unrolled
        probe = (h1 + jnp.uint32(j) * h2) % jnp.uint32(num_bits)
        word = words[(probe >> 5).astype(jnp.int32)]
        bit = (word >> (probe & jnp.uint32(31))) & jnp.uint32(1)
        hit &= bit == 1
    out_ref[0, :] = hit


@functools.partial(jax.jit, static_argnames=("num_hashes", "block_n", "interpret"))
def bloom_query(
    words: jnp.ndarray,  # u32 [Q, M/32] packed filters (one per query)
    v: jnp.ndarray,  # int32 [Q, N] vertex ids
    i: jnp.ndarray,  # int32 [Q, N] iterations
    salt: jnp.ndarray,  # int32 [Q] per-filter salt
    *,
    num_hashes: int = 4,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    q, mw = words.shape
    _, n = v.shape
    num_bits = mw * 32
    bn = min(block_n, n)
    npad = (bn - n % bn) % bn
    if npad:
        v = jnp.concatenate([v, jnp.zeros((q, npad), v.dtype)], 1)
        i = jnp.concatenate([i, jnp.zeros((q, npad), i.dtype)], 1)
    grid = (q, (n + npad) // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, num_hashes=num_hashes, num_bits=num_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, mw), lambda iq, ib: (iq, 0)),
            pl.BlockSpec((1, bn), lambda iq, ib: (iq, ib)),
            pl.BlockSpec((1, bn), lambda iq, ib: (iq, ib)),
            pl.BlockSpec((1,), lambda iq, ib: (iq,)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda iq, ib: (iq, ib)),
        out_shape=jax.ShapeDtypeStruct((q, n + npad), jnp.bool_),
        interpret=resolve_interpret(interpret),
    )(words, v, i, salt)
    return out[:, :n]


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """bool [..., M] → u32 [..., M/32] (M must be a 32-multiple)."""
    *lead, m = bits.shape
    assert m % 32 == 0
    b = bits.reshape(*lead, m // 32, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (b << shifts).sum(axis=-1, dtype=jnp.uint32)
