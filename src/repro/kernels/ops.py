"""Public jit'd wrappers for the Pallas kernels.

Every kernel signature defaults ``interpret=None`` → :func:`default_interpret`
(interpret off-TPU, compiled Mosaic on TPU; see ``kernels/interpret.py`` for
the one-time warning when interpret mode is forced on a TPU backend).
Callers can still force either mode explicitly.
"""

from __future__ import annotations

from repro.kernels.bloom import bloom_query, pack_bits  # noqa: F401
from repro.kernels.diff_lookup import diff_lookup  # noqa: F401
from repro.kernels.ell_spmv import ell_spmv  # noqa: F401
from repro.kernels.flash_attn import flash_attention  # noqa: F401
from repro.kernels.fused_sweep import FusedOut, fused_sweep  # noqa: F401
from repro.kernels.interpret import (  # noqa: F401
    default_interpret,
    resolve_interpret,
)


def spmv(states, nbr, w, carry, *, semiring="min_plus", **kw):
    return ell_spmv(states, nbr, w, carry, semiring=semiring, **kw)


def lookup(iters, vals, qi, **kw):
    return diff_lookup(iters, vals, qi, **kw)


def bloom(words, v, i, salt, **kw):
    return bloom_query(words, v, i, salt, **kw)


def attention(q, k, v, *, causal=True, **kw):
    return flash_attention(q, k, v, causal=causal, **kw)


def sweep(*args, **kw):
    """The fused maintenance megakernel (one dispatch per sweep iteration)."""
    return fused_sweep(*args, **kw)
