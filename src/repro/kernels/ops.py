"""Public jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernel body executes in Python
on CPU for validation) and to False on TPU backends, where the compiled
Mosaic kernel runs.  Callers can force either mode.
"""

from __future__ import annotations

import jax

from repro.kernels.bloom import bloom_query, pack_bits  # noqa: F401
from repro.kernels.diff_lookup import diff_lookup  # noqa: F401
from repro.kernels.ell_spmv import ell_spmv  # noqa: F401
from repro.kernels.flash_attn import flash_attention  # noqa: F401


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def spmv(states, nbr, w, carry, *, semiring="min_plus", **kw):
    kw.setdefault("interpret", default_interpret())
    return ell_spmv(states, nbr, w, carry, semiring=semiring, **kw)


def lookup(iters, vals, qi, **kw):
    kw.setdefault("interpret", default_interpret())
    return diff_lookup(iters, vals, qi, **kw)


def bloom(words, v, i, salt, **kw):
    kw.setdefault("interpret", default_interpret())
    return bloom_query(words, v, i, salt, **kw)


def attention(q, k, v, *, causal=True, **kw):
    kw.setdefault("interpret", default_interpret())
    return flash_attention(q, k, v, causal=causal, **kw)
