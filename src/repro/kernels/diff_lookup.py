"""Batched latest-change-point-≤-i lookup over the dense difference store.

The access path of AccessDᵢᵛWithDrops (paper §5.1): given per-key sorted
iteration rows ``iters [N, S]`` (IMAX-padded) and values ``vals [N, S]``,
find per key the latest stored iteration ≤ query ``i`` and its value.

Branch-free: rows are sorted so the insertion point is a ≤-count; the value
gather is a one-hot dot on the VPU (avoids a serializing dynamic gather).
Grid: N/BN tiles; the S axis rides entirely in VMEM (S is small: 8–64).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import resolve_interpret

IMAX = jnp.int32(jnp.iinfo(jnp.int32).max)


def _kernel(iters_ref, vals_ref, qi_ref, val_ref, it_ref, found_ref):
    it = iters_ref[...]  # [BN, S]
    vl = vals_ref[...]  # [BN, S]
    qi = qi_ref[...]  # [BN]
    le = (it <= qi[:, None]).astype(jnp.int32)
    idx = jnp.sum(le, axis=1) - 1  # [-1 .. S-1]
    found = idx >= 0
    onehot = (jax.lax.iota(jnp.int32, it.shape[1])[None, :] == idx[:, None])
    val = jnp.sum(jnp.where(onehot, vl, 0.0), axis=1)
    fit = jnp.sum(jnp.where(onehot, it, 0), axis=1)
    val_ref[...] = val
    it_ref[...] = jnp.where(found, fit, -1)
    found_ref[...] = found


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def diff_lookup(
    iters: jnp.ndarray,  # int32 [N, S] sorted ascending, IMAX padded
    vals: jnp.ndarray,  # f32 [N, S]
    qi: jnp.ndarray,  # int32 [N] query iteration per key
    *,
    block_n: int = 256,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    n, s = iters.shape
    bn = min(block_n, n)
    npad = (bn - n % bn) % bn
    if npad:
        iters = jnp.concatenate([iters, jnp.full((npad, s), IMAX, iters.dtype)], 0)
        vals = jnp.concatenate([vals, jnp.zeros((npad, s), vals.dtype)], 0)
        qi = jnp.concatenate([qi, jnp.zeros((npad,), qi.dtype)], 0)
    grid = ((n + npad) // bn,)
    val, fit, found = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, s), lambda i: (i, 0)),
            pl.BlockSpec((bn, s), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + npad,), vals.dtype),
            jax.ShapeDtypeStruct((n + npad,), jnp.int32),
            jax.ShapeDtypeStruct((n + npad,), jnp.bool_),
        ],
        interpret=resolve_interpret(interpret),
    )(iters, vals, qi)
    return val[:n], fit[:n], found[:n]
