"""Fused semiring SpMV over blocked-ELL in-adjacency (the IFE inner loop).

This is the TPU-native form of the paper's ExpandFrontier = Join + Min (§3.2)
and the kernel-level realization of JOD (§4): the per-edge Join output J is
*never materialized to HBM* — messages are formed in VREGs from a VMEM-
resident state block and reduced immediately.

Layout (see ``GraphSnapshot.to_ell``):
    states [Q, Vp]      vertex states, padded with the reduce identity at
                        index V (ELL padding sentinel rows point there)
    nbr    [V, D]       in-neighbour ids (== V on padding slots)
    w      [V, D]       edge weights
    out    [Q, V]       aggregated new states (carry folded in)

Grid: (Q, V/BV).  Per step the kernel holds one [1, Vp] state row and one
[BV, D] adjacency tile in VMEM; the gather hits VMEM, the ⊗ (msg) and ⊕
(reduce) run on the VPU; D is padded to a lane multiple.  VMEM footprint is
Vp·4 + 2·BV·D·4 + BV·4 bytes — BV is chosen so this fits ~16 MB.

**Shape contract (no hidden copies).**  The kernel never pads or copies its
operands inside the jitted call: the row count ``V`` must be a multiple of
the effective block (``min(block_v, V)``), or the whole extent runs as one
tile.  Callers that want the blocked grid for a non-aligned ``V`` pad ONCE
at build time via ``GraphSnapshot.to_ell(row_multiple=block_v)`` — padding
rows are sentinel rows (they gather the identity) and their outputs are
sliced off by the caller.

Semirings: min_plus (SPSP/SSSP), min_hop (K-hop/RPQ reachability),
min_label (WCC), pr_sum (PageRank).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.interpret import resolve_interpret

SEMIRINGS = ("min_plus", "min_hop", "min_label", "pr_sum")


def expand_tile(semiring: str, hop_cap: float, row, nbr, w, carry):
    """One blocked-ELL expand tile: gather + ⊗ (msg) + ⊕ (reduce) + carry.

    ``row`` is the full [Vp] state row (identity at the sentinel index),
    ``nbr``/``w`` a [BV, D] adjacency tile, ``carry`` the matching [BV]
    carry slice (prev states for min-*, teleport base for pr_sum).  Shared
    by :func:`ell_spmv` and the fused maintenance megakernel so both paths
    produce bit-identical values.
    """
    s = row[nbr]  # VMEM gather → [BV, D]
    if semiring == "min_plus":
        msgs = s + w
        red = jnp.min(msgs, axis=1)
        return jnp.minimum(red, carry)
    if semiring == "min_hop":
        msgs = s + 1.0
        if hop_cap != float("inf"):  # K-hop truncation, baked in at trace time
            msgs = jnp.where(msgs > hop_cap, jnp.inf, msgs)
        red = jnp.min(msgs, axis=1)
        return jnp.minimum(red, carry)
    if semiring == "min_label":
        msgs = s  # propagate the label itself
        red = jnp.min(msgs, axis=1)
        return jnp.minimum(red, carry)
    if semiring == "pr_sum":
        msgs = s * w  # w = alpha / outdeg(src); identity slot holds state 0
        red = jnp.sum(msgs, axis=1)
        return red + carry  # carry block holds the teleport base
    raise ValueError(semiring)


def _kernel(
    states_ref, nbr_ref, w_ref, carry_ref, out_ref, *, semiring: str, hop_cap: float
):
    out_ref[0, :] = expand_tile(
        semiring, hop_cap, states_ref[0, :], nbr_ref[...], w_ref[...], carry_ref[0, :]
    )


def block_rows(block_v: int, v: int) -> int:
    """Effective row-block: ``min(block_v, v)``, falling back to a single
    tile when ``v`` is not a multiple — the kernel NEVER pads operands."""
    bv = min(block_v, v)
    if v % bv:
        bv = v
    return bv


@functools.partial(
    jax.jit, static_argnames=("semiring", "block_v", "interpret", "hop_cap")
)
def ell_spmv(
    states: jnp.ndarray,  # [Q, Vp]  (identity sentinel at index Vp - 1)
    nbr: jnp.ndarray,  # [V, D]  (global ids into the state row; Vp-1 padding)
    w: jnp.ndarray,  # [V, D]
    carry: jnp.ndarray,  # [Q, V]  (prev states for min-*, teleport for pr)
    *,
    semiring: str = "min_plus",
    block_v: int = 128,
    interpret: bool | None = None,
    hop_cap: float = float("inf"),
) -> jnp.ndarray:
    """Unsharded: Vp = V + 1.  Under the vertex-sharded sweep each shard
    passes its LOCAL adjacency rows (V = V_global / n) against the full
    all-gathered state row (Vp = V_global + 1) — the gather indices stay
    global, so the kernel body is identical; only the output extent shrinks.

    ``V`` here is the nbr/w row count, which may include build-time padding
    rows (``to_ell(row_multiple=...)``); the caller slices those off.  The
    operands are used as-is — already-padded inputs hit one compiled program
    with zero per-call copies (see the module shape contract).
    """
    assert semiring in SEMIRINGS
    q, vp = states.shape
    v, d = nbr.shape
    # boundary shape contract: no implicit padding happens past this point
    assert w.shape == (v, d), (w.shape, nbr.shape)
    assert vp >= v + 1 and carry.shape == (q, v), (states.shape, carry.shape)
    bv = block_rows(block_v, v)
    grid = (q, v // bv)
    return pl.pallas_call(
        functools.partial(_kernel, semiring=semiring, hop_cap=hop_cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, vp), lambda iq, iv: (iq, 0)),  # full state row
            pl.BlockSpec((bv, d), lambda iq, iv: (iv, 0)),
            pl.BlockSpec((bv, d), lambda iq, iv: (iv, 0)),
            pl.BlockSpec((1, bv), lambda iq, iv: (iq, iv)),
        ],
        out_specs=pl.BlockSpec((1, bv), lambda iq, iv: (iq, iv)),
        out_shape=jax.ShapeDtypeStruct((q, v), states.dtype),
        interpret=resolve_interpret(interpret),
    )(states, nbr, w, carry)
