"""Fused semiring SpMV over blocked-ELL in-adjacency (the IFE inner loop).

This is the TPU-native form of the paper's ExpandFrontier = Join + Min (§3.2)
and the kernel-level realization of JOD (§4): the per-edge Join output J is
*never materialized to HBM* — messages are formed in VREGs from a VMEM-
resident state block and reduced immediately.

Layout (see ``GraphSnapshot.to_ell``):
    states [Q, Vp]      vertex states, padded with the reduce identity at
                        index V (ELL padding sentinel rows point there)
    nbr    [V, D]       in-neighbour ids (== V on padding slots)
    w      [V, D]       edge weights
    out    [Q, V]       aggregated new states (carry folded in)

Grid: (Q, V/BV).  Per step the kernel holds one [1, Vp] state row and one
[BV, D] adjacency tile in VMEM; the gather hits VMEM, the ⊗ (msg) and ⊕
(reduce) run on the VPU; D is padded to a lane multiple.  VMEM footprint is
Vp·4 + 2·BV·D·4 + BV·4 bytes — BV is chosen so this fits ~16 MB.

Semirings: min_plus (SPSP/SSSP), min_hop (K-hop/RPQ reachability),
min_label (WCC), pr_sum (PageRank).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SEMIRINGS = ("min_plus", "min_hop", "min_label", "pr_sum")


def _kernel(
    states_ref, nbr_ref, w_ref, carry_ref, out_ref, *, semiring: str, hop_cap: float
):
    nbr = nbr_ref[...]  # [BV, D] int32
    w = w_ref[...]  # [BV, D] f32
    row = states_ref[0, :]  # [Vp] f32 (VMEM-resident state row)
    s = row[nbr]  # VMEM gather → [BV, D]

    if semiring == "min_plus":
        msgs = s + w
        red = jnp.min(msgs, axis=1)
        out = jnp.minimum(red, carry_ref[0, :])
    elif semiring == "min_hop":
        msgs = s + 1.0
        if hop_cap != float("inf"):  # K-hop truncation, baked in at trace time
            msgs = jnp.where(msgs > hop_cap, jnp.inf, msgs)
        red = jnp.min(msgs, axis=1)
        out = jnp.minimum(red, carry_ref[0, :])
    elif semiring == "min_label":
        msgs = s  # propagate the label itself
        red = jnp.min(msgs, axis=1)
        out = jnp.minimum(red, carry_ref[0, :])
    elif semiring == "pr_sum":
        msgs = s * w  # w = alpha / outdeg(src); identity slot holds state 0
        red = jnp.sum(msgs, axis=1)
        out = red + carry_ref[0, :]  # carry block holds the teleport base
    else:
        raise ValueError(semiring)
    out_ref[0, :] = out


@functools.partial(
    jax.jit, static_argnames=("semiring", "block_v", "interpret", "hop_cap")
)
def ell_spmv(
    states: jnp.ndarray,  # [Q, Vp]  (identity sentinel at index Vp - 1)
    nbr: jnp.ndarray,  # [V, D]  (global ids into the state row; Vp-1 padding)
    w: jnp.ndarray,  # [V, D]
    carry: jnp.ndarray,  # [Q, V]  (prev states for min-*, teleport for pr)
    *,
    semiring: str = "min_plus",
    block_v: int = 128,
    interpret: bool = True,
    hop_cap: float = float("inf"),
) -> jnp.ndarray:
    """Unsharded: Vp = V + 1.  Under the vertex-sharded sweep each shard
    passes its LOCAL adjacency rows (V = V_global / n) against the full
    all-gathered state row (Vp = V_global + 1) — the gather indices stay
    global, so the kernel body is identical; only the output extent shrinks.
    """
    assert semiring in SEMIRINGS
    q, vp = states.shape
    v, d = nbr.shape
    assert vp >= v + 1 and carry.shape == (q, v)
    sentinel = vp - 1  # identity slot padded ELL cells gather from
    bv = min(block_v, v)
    # pad V to a BV multiple; padded rows gather from the identity slot
    vpad = (bv - v % bv) % bv
    if vpad:
        nbr = jnp.concatenate([nbr, jnp.full((vpad, d), sentinel, nbr.dtype)], 0)
        w = jnp.concatenate([w, jnp.zeros((vpad, d), w.dtype)], 0)
        carry = jnp.concatenate([carry, jnp.zeros((q, vpad), carry.dtype)], 1)
    grid = (q, (v + vpad) // bv)
    out = pl.pallas_call(
        functools.partial(_kernel, semiring=semiring, hop_cap=hop_cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, vp), lambda iq, iv: (iq, 0)),  # full state row
            pl.BlockSpec((bv, d), lambda iq, iv: (iv, 0)),
            pl.BlockSpec((bv, d), lambda iq, iv: (iv, 0)),
            pl.BlockSpec((1, bv), lambda iq, iv: (iq, iv)),
        ],
        out_specs=pl.BlockSpec((1, bv), lambda iq, iv: (iq, iv)),
        out_shape=jax.ShapeDtypeStruct((q, v + vpad), states.dtype),
        interpret=interpret,
    )(states, nbr, w, carry)
    return out[:, :v]
