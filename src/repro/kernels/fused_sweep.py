"""Fused per-iteration maintenance megakernel (DESIGN.md §13).

One ``pl.pallas_call`` per sweep iteration runs the whole per-vertex inner
loop of the paper's maintenance procedure as a single circuit:

    frontier expand over blocked-ELL adjacency (Join + semiring ⊕, all four
    semirings — shared tile with :mod:`repro.kernels.ell_spmv`)
      → DroppedVT probe (Det store rows or Bloom bits, in VMEM)
      → change-point detection vs the frozen pre-update trajectory
      → per-query drop selection (the governor's ``DropParams`` rows)
      → difference-store append / overwrite / eviction / removal
      → Det-Drop register/unregister (det mode; fully in-kernel)
      → exact-front advance (``cur``)

The stitched path dispatches these as ≥3 separate device programs with HBM
round trips between every stage; here the candidate-diff tile, the [BV, S]
diff-store rows and the intermediate J messages live entirely in VMEM.

**Bit-parity by construction**: the kernel body calls the *same* library
functions the stitched sweep uses — :func:`repro.core.diffstore.upsert` /
``value_at`` / ``remove_at`` / ``has_at``, :func:`repro.core.dropping.
select_to_drop` and :func:`repro.core.bloom.query` — on VMEM-resident tiles,
so every arithmetic op and reduction order is identical to the stitched path.

Grid: ``(Q, V/BV)`` (adaptive single tile when V is not a BV multiple — the
kernel never pads operands, same contract as ``ell_spmv``).  Per-tile VMEM:
the [1, Vp] gathered state row, one [BV, D] adjacency tile, the [1, BV, S]
diff-store rows (+ [1, BV, S_d] Det rows or the [1, M] Bloom row) and ~12
[1, BV] mask/value tiles.

Division of labour with the engine (what stays OUTSIDE the kernel):

* ``sched`` (frontier ∪ dirty) and the next frontier push — schedule
  bookkeeping over the COO edge list (segment ops), not per-vertex dataflow;
* VDC's J-store maintenance + aggregate — edge-store dataflow ([Q, E] rows);
  the fused path then takes the precomputed ``new`` (partial fusion);
* Bloom *insert* (prob mode) — an XLA scatter; an in-VMEM insert would cost
  O(BV·k·M) lane compares per tile.  The kernel emits the to-drop/evicted
  masks and the engine folds them into the filter;
* the sharded-drop collectives (psum/pmax) — cross-device by definition.

None of those are ``pallas_call``s, so the fused sweep issues exactly ONE
kernel dispatch per iteration.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bloom as bloom_lib
from repro.core import diffstore as ds
from repro.core import dropping as dr
from repro.kernels.ell_spmv import SEMIRINGS, block_rows, expand_tile
from repro.kernels.interpret import resolve_interpret


class FusedOut(NamedTuple):
    """Per-vertex outputs of one fused sweep iteration (all [Q, V]-shaped,
    stores [Q, V, S]); the engine derives stats sums and the next frontier
    from the masks."""

    d_iters: jnp.ndarray  # int32 [Q, V, S] — updated diff-store rows
    d_vals: jnp.ndarray  # f32  [Q, V, S]
    d_count: jnp.ndarray  # int32 [Q, V]
    cur: jnp.ndarray  # f32 [Q, V] — exact D_i (the advanced front)
    old: jnp.ndarray  # f32 [Q, V] — pre-update trajectory value at i
    stale: jnp.ndarray  # bool — old trajectory obscured by a dropped diff
    changed: jnp.ndarray  # bool — value differs from the old trajectory
    repair: jnp.ndarray  # bool — dropped change point recomputed at i
    to_store: jnp.ndarray  # bool — change point written at i
    to_drop: jnp.ndarray  # bool — change point dropped at i
    vanish: jnp.ndarray  # bool — stored change point cancelled at i
    evicted: jnp.ndarray  # bool — row shed its oldest point on insert
    evicted_iter: jnp.ndarray  # int32 — that point's iteration
    det_iters: jnp.ndarray | None = None  # int32 [Q, V, S_d] (det mode)
    det_count: jnp.ndarray | None = None  # int32 [Q, V]
    det_overflow: jnp.ndarray | None = None  # int32 [Q, nv] per-tile partials
    det_max_iter: jnp.ndarray | None = None  # int32 [Q, nv] per-tile partials


def _kernel(
    scal_ref,
    *refs,
    semiring: str,
    hop_cap: float,
    block_v: int,
    drop_mode: str,
    bloom_hashes: int,
    compute_new: bool,
    num_out: int,
):
    ins, outs = refs[: len(refs) - num_out], refs[len(refs) - num_out :]
    ins = list(ins)
    i = scal_ref[0, 0]
    off = scal_ref[0, 1]
    iq = pl.program_id(0)
    iv = pl.program_id(1)

    # ---- stage 1: expand (JOD: in-kernel ELL tile; VDC: precomputed new)
    if compute_new:
        states_ref, nbr_ref, w_ref, kcarry_ref = ins[:4]
        del ins[:4]
        new = expand_tile(
            semiring,
            hop_cap,
            states_ref[0, :],
            nbr_ref[...],
            w_ref[...],
            kcarry_ref[0, :],
        )[None, :]
    else:
        new = ins.pop(0)[...]

    sched = ins.pop(0)[...]  # [1, BV] bool
    cur = ins.pop(0)[...]
    cur_old = ins.pop(0)[...]
    stale_old = ins.pop(0)[...]
    act = ins.pop(0)[...]  # [1, 1] bool — this query row's active flag
    dstore0 = ds.DiffStore(ins.pop(0)[...], ins.pop(0)[...], ins.pop(0)[...])
    old_store = ds.DiffStore(ins.pop(0)[...], ins.pop(0)[...], None)

    if drop_mode != "none":
        degree = ins.pop(0)[...]  # [1, BV] f32
        params = dr.DropParams(*(ins.pop(0)[...] for _ in dr.DropParams._fields))
    if drop_mode == "det":
        det_iters = ins.pop(0)[...]  # [1, BV, S_d]
        det_count = ins.pop(0)[...]  # [1, BV]
        det0 = ds.DiffStore(
            det_iters, jnp.zeros(det_iters.shape, jnp.float32), det_count
        )
    if drop_mode == "prob":
        flt = bloom_lib.BloomFilter(ins.pop(0)[...], num_hashes=bloom_hashes)

    # global ids of this tile (the drop coin and Bloom keys hash global ids,
    # so decisions are independent of sharding and tiling)
    v_ids = off + iv * block_v + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_v), 1
    )
    q_ids = jnp.full((1, 1), iq, jnp.int32)

    # ---- stage 2: DroppedVT probe → repair mask (AccessDᵢᵛWithDrops)
    if drop_mode == "det":
        dropped_here = ds.has_at(det0, i)
    elif drop_mode == "prob":
        it = jnp.broadcast_to(i, v_ids.shape)
        dropped_here = bloom_lib.query(flt, v_ids, it, salt=q_ids)
    else:
        dropped_here = jnp.zeros_like(sched)
    repair = dropped_here & act & ~sched

    # ---- stage 3: change-point detection vs the frozen old trajectory
    old_has, old_val = ds.value_at(old_store, i)
    old_i = jnp.where(old_has, old_val, cur_old)
    stale = (stale_old | dropped_here) & ~old_has
    changed = sched & ((new != old_i) | stale)

    # ---- stage 4: drop selection + diff-store append/remove (all in VMEM)
    want_point = sched & (new != cur)
    has_cur, cur_stored_val = ds.value_at(dstore0, i)
    if drop_mode != "none":
        to_drop = want_point & dr.select_to_drop(params, degree, q_ids, v_ids, i)
        to_store = want_point & ~to_drop
    else:
        to_drop = jnp.zeros_like(want_point)
        to_store = want_point
    dstore, evicted, evicted_iter = ds.upsert(dstore0, i, to_store, new)
    vanish = sched & ~want_point & has_cur
    dstore = ds.remove_at(dstore, i, (to_drop & has_cur) | vanish)

    # ---- stage 5: exact-front advance
    recompute = sched | repair
    cur_next = jnp.where(recompute, new, jnp.where(has_cur, cur_stored_val, cur))

    outs = list(outs)
    outs.pop(0)[...] = dstore.iters
    outs.pop(0)[...] = dstore.vals
    outs.pop(0)[...] = dstore.count
    outs.pop(0)[...] = cur_next
    outs.pop(0)[...] = old_i
    outs.pop(0)[...] = stale
    outs.pop(0)[...] = changed
    outs.pop(0)[...] = repair
    outs.pop(0)[...] = to_store
    outs.pop(0)[...] = to_drop
    outs.pop(0)[...] = vanish
    outs.pop(0)[...] = evicted
    outs.pop(0)[...] = evicted_iter

    # ---- stage 6 (det mode): DroppedVT register/unregister, in-kernel.
    #      Same call sequence as dr.register/dr.unregister on the stitched
    #      path; overflow/max-iter are per-tile partials the engine reduces.
    if drop_mode == "det":
        zeros = jnp.zeros(to_drop.shape, jnp.float32)
        det1, ev1, _ = ds.upsert(det0, i, to_drop, zeros)
        hi1 = jnp.where(to_drop, i, -1).max()
        det2, ev2, _ = ds.upsert(det1, evicted_iter, evicted, zeros)
        hi2 = jnp.where(evicted, evicted_iter, -1).max()
        det3 = ds.remove_at(det2, i, to_store | vanish)
        outs.pop(0)[...] = det3.iters
        outs.pop(0)[...] = det3.count
        outs.pop(0)[0, 0] = (ev1.sum() + ev2.sum()).astype(jnp.int32)
        outs.pop(0)[0, 0] = jnp.maximum(hi1, hi2)


def fused_sweep(
    i,  # int32 scalar — the sweep iteration
    off,  # int32 scalar — global vertex offset of this partition
    sched,  # bool [Q, V] — vertices whose aggregator reruns at i
    active,  # bool [Q] — live query slots
    cur,  # f32 [Q, V] — exact D_{i-1}
    cur_old,  # f32 [Q, V] — pre-update trajectory at i-1
    stale_old,  # bool [Q, V]
    dstore: ds.DiffStore,  # [Q, V, S] — the Iterate difference store
    old_dstore: ds.DiffStore,  # frozen pre-maintenance snapshot
    *,
    states=None,  # f32 [Q, Vp] gathered front + identity sentinel (JOD)
    nbr=None,  # int32 [V, D] blocked-ELL in-adjacency (JOD)
    w=None,  # f32 [V, D]
    kcarry=None,  # f32 [Q, V] kernel carry (prev states / teleport base)
    new=None,  # f32 [Q, V] precomputed D_i candidates (VDC partial fusion)
    degree=None,  # f32 [1, V] total degree (drop selection)
    params: dr.DropParams | None = None,  # [Q] selection rows
    det: ds.DiffStore | None = None,  # [Q, V, S_d] Det-Drop store
    bloom_bits=None,  # bool [Q, M] Bloom rows (probe only)
    bloom_hashes: int = 4,
    semiring: str = "min_plus",
    hop_cap: float = float("inf"),
    block_v: int = 128,
    drop_mode: str = "none",
    interpret: bool | None = None,
) -> FusedOut:
    """One fused maintenance iteration: a single ``pallas_call`` dispatch.

    Exactly one of ``states``/``nbr``/``w``/``kcarry`` (JOD: expand runs
    in-kernel) or ``new`` (VDC: the aggregate ran outside) must be given.
    Shapes follow the engine's local partition — under ``shard_map`` every
    [·, V] argument is the shard's slice and ``off`` its global offset.
    """
    assert semiring in SEMIRINGS
    assert drop_mode in ("none", "det", "prob")
    compute_new = new is None
    if compute_new:
        assert states is not None and nbr is not None and kcarry is not None
    q, num_local = sched.shape
    s_cap = dstore.capacity
    s_old = old_dstore.capacity
    bv = block_rows(block_v, num_local)
    nv = num_local // bv
    grid = (q, nv)

    def tile2(ix=lambda iq, iv: (iq, iv)):
        return pl.BlockSpec((1, bv), ix)

    def tile3(s):
        return pl.BlockSpec((1, bv, s), lambda iq, iv: (iq, iv, 0))

    scal = jnp.stack(
        [jnp.asarray(i, jnp.int32), jnp.asarray(off, jnp.int32)]
    ).reshape(1, 2)
    args = [scal]
    in_specs = [pl.BlockSpec((1, 2), lambda iq, iv: (0, 0))]

    if compute_new:
        vp = states.shape[1]
        d = nbr.shape[1]
        assert nbr.shape == (num_local, d) and w.shape == (num_local, d)
        assert kcarry.shape == (q, num_local) and vp >= num_local + 1
        args += [states, nbr, w, kcarry]
        in_specs += [
            pl.BlockSpec((1, vp), lambda iq, iv: (iq, 0)),
            pl.BlockSpec((bv, d), lambda iq, iv: (iv, 0)),
            pl.BlockSpec((bv, d), lambda iq, iv: (iv, 0)),
            tile2(),
        ]
    else:
        args += [new]
        in_specs += [tile2()]

    args += [sched, cur, cur_old, stale_old, active[:, None]]
    in_specs += [tile2()] * 4 + [pl.BlockSpec((1, 1), lambda iq, iv: (iq, 0))]
    args += [dstore.iters, dstore.vals, dstore.count]
    in_specs += [tile3(s_cap), tile3(s_cap), tile2()]
    args += [old_dstore.iters, old_dstore.vals]
    in_specs += [tile3(s_old), tile3(s_old)]

    if drop_mode != "none":
        assert degree is not None and params is not None
        args += [degree]
        in_specs += [tile2(lambda iq, iv: (0, iv))]
        for f in dr.DropParams._fields:
            args.append(getattr(params, f))
            in_specs.append(pl.BlockSpec((1,), lambda iq, iv: (iq,)))
    if drop_mode == "det":
        assert det is not None
        args += [det.iters, det.count]
        in_specs += [tile3(det.capacity), tile2()]
    if drop_mode == "prob":
        assert bloom_bits is not None
        m = bloom_bits.shape[-1]
        args += [bloom_bits]
        in_specs += [pl.BlockSpec((1, m), lambda iq, iv: (iq, 0))]

    def o2(dtype):
        return jax.ShapeDtypeStruct((q, num_local), dtype), tile2()

    def o3(s, dtype=jnp.int32):
        return jax.ShapeDtypeStruct((q, num_local, s), dtype), tile3(s)

    out_shapes, out_specs = [], []
    for shp, spec in [
        o3(s_cap),
        o3(s_cap, jnp.float32),
        o2(jnp.int32),
        o2(jnp.float32),  # cur
        o2(jnp.float32),  # old
        o2(jnp.bool_),  # stale
        o2(jnp.bool_),  # changed
        o2(jnp.bool_),  # repair
        o2(jnp.bool_),  # to_store
        o2(jnp.bool_),  # to_drop
        o2(jnp.bool_),  # vanish
        o2(jnp.bool_),  # evicted
        o2(jnp.int32),  # evicted_iter
    ]:
        out_shapes.append(shp)
        out_specs.append(spec)
    if drop_mode == "det":
        for shp, spec in [
            o3(det.capacity),
            o2(jnp.int32),
            (
                jax.ShapeDtypeStruct((q, nv), jnp.int32),
                pl.BlockSpec((1, 1), lambda iq, iv: (iq, iv)),
            ),
            (
                jax.ShapeDtypeStruct((q, nv), jnp.int32),
                pl.BlockSpec((1, 1), lambda iq, iv: (iq, iv)),
            ),
        ]:
            out_shapes.append(shp)
            out_specs.append(spec)

    out = pl.pallas_call(
        functools.partial(
            _kernel,
            semiring=semiring,
            hop_cap=hop_cap,
            block_v=bv,
            drop_mode=drop_mode,
            bloom_hashes=int(bloom_hashes),
            compute_new=compute_new,
            num_out=len(out_shapes),
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=resolve_interpret(interpret),
    )(*args)
    base = FusedOut(*out[:13])
    if drop_mode == "det":
        base = base._replace(
            det_iters=out[13],
            det_count=out[14],
            det_overflow=out[15],
            det_max_iter=out[16],
        )
    return base
