"""Interpret-mode policy for the Pallas kernels.

Every kernel signature defaults ``interpret=None``; :func:`resolve_interpret`
maps ``None`` to the backend default — interpret off-TPU (the kernel body
executes in Python for validation), compiled Mosaic on TPU.  A caller that
*forces* interpret mode on a TPU backend is almost certainly measuring the
Python emulation instead of the kernel, so the first such resolution logs a
one-time warning.

This module is a leaf (no intra-package imports) so the kernels can use it
without creating an import cycle with :mod:`repro.kernels.ops`.
"""

from __future__ import annotations

import logging

import jax

_log = logging.getLogger("repro.kernels")
_warned_tpu_interpret = False


def default_interpret() -> bool:
    """True off-TPU (Python emulation), False on TPU (compiled Mosaic)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel's ``interpret`` argument (``None`` → backend default).

    Logs once when interpret mode ends up running on a TPU backend — the
    emulated kernel is orders of magnitude slower than the Mosaic lowering
    and silently hides the kernel's real cost.
    """
    if interpret is None:
        interpret = default_interpret()
    if interpret and jax.default_backend() == "tpu":
        global _warned_tpu_interpret
        if not _warned_tpu_interpret:
            _warned_tpu_interpret = True
            _log.warning(
                "Pallas kernel running in interpret mode on a TPU backend: "
                "this executes the kernel body in Python instead of the "
                "compiled Mosaic kernel. Pass interpret=False (or leave it "
                "None) to use the hardware path."
            )
    return bool(interpret)
