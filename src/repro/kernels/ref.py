"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""

from __future__ import annotations

import jax.numpy as jnp


def ell_spmv_ref(
    states, nbr, w, carry, *, semiring: str, hop_cap: float = float("inf")
) -> jnp.ndarray:
    """states [Q, Vp], nbr/w [V, D], carry [Q, V] → [Q, V]."""
    s = states[:, nbr]  # [Q, V, D]
    if semiring == "min_plus":
        red = jnp.min(s + w[None], axis=-1)
        return jnp.minimum(red, carry)
    if semiring == "min_hop":
        msgs = s + 1.0
        msgs = jnp.where(msgs > hop_cap, jnp.inf, msgs)
        red = jnp.min(msgs, axis=-1)
        return jnp.minimum(red, carry)
    if semiring == "min_label":
        red = jnp.min(s, axis=-1)
        return jnp.minimum(red, carry)
    if semiring == "pr_sum":
        red = jnp.sum(s * w[None], axis=-1)
        return red + carry
    raise ValueError(semiring)


def diff_lookup_ref(iters, vals, qi):
    """iters/vals [N, S], qi [N] → (val [N], iter [N], found [N])."""
    mask = iters <= qi[:, None]
    idx = mask.sum(axis=1) - 1
    found = idx >= 0
    safe = jnp.maximum(idx, 0)
    val = jnp.take_along_axis(vals, safe[:, None], axis=1)[:, 0]
    fit = jnp.take_along_axis(iters, safe[:, None], axis=1)[:, 0]
    return jnp.where(found, val, 0.0), jnp.where(found, fit, -1), found


def bloom_query_ref(words, v, i, salt, *, num_hashes: int):
    """Packed-word Bloom query, same double hashing as the kernel."""
    from repro.kernels.bloom import hash_pair

    num_bits = words.shape[-1] * 32
    h1, h2 = hash_pair(v, i, salt[:, None])
    j = jnp.arange(num_hashes, dtype=jnp.uint32)
    probes = (h1[..., None] + j * h2[..., None]) % jnp.uint32(num_bits)  # [Q,N,k]
    word = jnp.take_along_axis(
        words[:, None, :], (probes >> 5).astype(jnp.int32), axis=-1
    )
    bit = (word >> (probes & jnp.uint32(31))) & jnp.uint32(1)
    return (bit == 1).all(axis=-1)


def attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """Naive softmax attention with GQA head mapping. [B,Hq,S,D]."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32))
    s = s / (d**0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)).astype(q.dtype)
