"""Chunked online-softmax attention (flash attention) for the LM archs.

Used by 32k-prefill and training: O(Sq·Bk) VMEM instead of the O(Sq·Sk)
HBM score matrix.  GQA is handled in the wrapper by mapping each query head
to its KV head via the grid index (no KV duplication in HBM).

Grid: (B, Hq, Sq/BQ, Sk/BK) — the minor (last) axis iterates sequentially on
TPU, so the kernel accumulates over KV blocks with running max/sum scratch
(the standard flash recurrence), initializing at k==0 and emitting the
normalized output at the last KV block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.interpret import resolve_interpret

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, causal, bq, bk
):
    kv_idx = pl.program_id(3)
    q_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # [BQ, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [BK, D]
    v = v_ref[0, 0].astype(jnp.float32)  # [BK, D]
    s = q @ k.T  # [BQ, BK] — MXU

    if causal:
        rows = q_idx * bq + jax.lax.iota(jnp.int32, bq)[:, None]
        cols = kv_idx * bk + jax.lax.iota(jnp.int32, bk)[None, :]
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])  # [BQ, BK]
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_new = acc_prev * alpha[:, None] + p @ v  # MXU

    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(kv_idx == pl.num_programs(3) - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Sk, D]
    v: jnp.ndarray,  # [B, Hkv, Sk, D]
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv  # GQA: query heads per KV head
    bq, bk = min(block_q, sq), min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0
    scale = 1.0 / (d**0.5)
    grid = (b, hq, sq // bq, sk // bk)
    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, bq=bq, bk=bk
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            # GQA: query head ih reads KV head ih // group
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),  # running max m
            pltpu.VMEM((bq,), jnp.float32),  # running denom l
            pltpu.VMEM((bq, d), jnp.float32),  # running numerator acc
        ],
        interpret=resolve_interpret(interpret),
    )(q, k, v)
