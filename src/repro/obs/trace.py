"""Low-overhead span/event tracer with Chrome-trace (Perfetto) JSON export.

Design constraints, in order:

1. **Zero cost when off.**  The disabled path allocates nothing: ``span()``
   returns a module-level singleton whose ``__enter__``/``__exit__`` are
   empty, and ``instant``/``counter_event`` return before touching the
   clock.  The only per-call overhead is one attribute load and one branch.
2. **Bounded memory when on.**  Events land in a ``collections.deque`` with
   ``maxlen`` (drop-oldest).  A long-running server with tracing enabled
   holds at most ``capacity`` events; ``dropped_events`` counts the loss so
   an exported trace is honest about truncation.
3. **Monotonic time.**  ``time.perf_counter_ns`` for both timestamps and
   durations — wall-clock steps (NTP) never tear a span.

Span taxonomy (``cat`` in the exported trace; see DESIGN.md §15):

- ``update_batch``   — one host-level δE ingestion (``apply_updates[_batched]``)
- ``sweep``          — one maintenance sweep dispatch (stats in ``args``)
- ``kernel_dispatch``— one jitted chunk step inside a batched ingestion
- ``repair``         — repair-on-access work (reassembly / scratch fallback)
- ``governor``       — shed / ladder-escalation actions
- ``checkpoint``     — checkpoint write / restore
- ``admission``      — serving-tier admission decisions (instant events)

Attribution rides in ``args`` (engine / shard / tenant / query / operator)
plus the Chrome-trace ``pid``/``tid`` fields: ``pid`` is the process-level
group (engine name), ``tid`` the within-group lane (e.g. shard or qid), so
Perfetto renders one track per lane.

The exported file is the Chrome Trace Event Format JSON object form::

    {"traceEvents": [...], "displayTimeUnit": "ms"}

with ``ph: "X"`` complete events (``ts``/``dur`` in microseconds),
``ph: "i"`` instants, and ``ph: "C"`` counter samples — loadable directly
in https://ui.perfetto.dev or ``chrome://tracing``.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any

__all__ = [
    "Tracer",
    "NULL_SPAN",
    "get_tracer",
    "set_tracer",
    "span",
    "instant",
    "counter_event",
]

DEFAULT_CAPACITY = 65536


class _NullSpan:
    """Singleton no-op context manager: the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: object) -> None:
        return None

    def set(self, **kwargs: Any) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class _Span:
    """A live complete-event ('X') span.  Created only when tracing is on."""

    __slots__ = ("_tracer", "name", "cat", "pid", "tid", "args", "_t0")

    def __init__(
        self,
        tracer: "Tracer",
        name: str,
        cat: str,
        pid: str,
        tid: str | int,
        args: dict[str, Any] | None,
    ) -> None:
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.pid = pid
        self.tid = tid
        self.args = args
        self._t0 = 0

    def set(self, **kwargs: Any) -> "_Span":
        """Attach attribution after the fact (e.g. sweep stats on exit)."""
        if self.args is None:
            self.args = kwargs
        else:
            self.args.update(kwargs)
        return self

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc: object) -> None:
        t1 = time.perf_counter_ns()
        self._tracer._emit(
            {
                "name": self.name,
                "cat": self.cat,
                "ph": "X",
                "ts": (self._t0 - self._tracer._epoch_ns) / 1e3,
                "dur": (t1 - self._t0) / 1e3,
                "pid": self.pid,
                "tid": self.tid,
                "args": self.args or {},
            }
        )


class Tracer:
    """Bounded-buffer span/event recorder.

    Thread-safe: the serving tier records from executor threads; deque
    appends are atomic under the GIL but export snapshots take the lock so
    a concurrent flush never sees a torn buffer.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY, enabled: bool = True):
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._buf: deque[dict[str, Any]] = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._epoch_ns = time.perf_counter_ns()
        self.emitted_events = 0

    # ---------------------------------------------------------------- record
    def _emit(self, ev: dict[str, Any]) -> None:
        self.emitted_events += 1
        self._buf.append(ev)

    def span(
        self,
        name: str,
        cat: str = "",
        *,
        pid: str = "repro",
        tid: str | int = 0,
        **args: Any,
    ) -> _Span | _NullSpan:
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, cat, pid, tid, args or None)

    def instant(
        self,
        name: str,
        cat: str = "",
        *,
        pid: str = "repro",
        tid: str | int = 0,
        **args: Any,
    ) -> None:
        if not self.enabled:
            return
        self._emit(
            {
                "name": name,
                "cat": cat,
                "ph": "i",
                "s": "t",  # thread-scoped instant
                "ts": (time.perf_counter_ns() - self._epoch_ns) / 1e3,
                "pid": pid,
                "tid": tid,
                "args": args,
            }
        )

    def counter(
        self,
        name: str,
        values: dict[str, float],
        *,
        pid: str = "repro",
        tid: str | int = 0,
    ) -> None:
        """Chrome-trace 'C' sample: Perfetto renders a stacked counter track."""
        if not self.enabled:
            return
        self._emit(
            {
                "name": name,
                "ph": "C",
                "ts": (time.perf_counter_ns() - self._epoch_ns) / 1e3,
                "pid": pid,
                "tid": tid,
                "args": values,
            }
        )

    # ---------------------------------------------------------------- export
    @property
    def dropped_events(self) -> int:
        return self.emitted_events - len(self._buf)

    def events(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()

    def chrome_trace(self) -> dict[str, Any]:
        """The Chrome Trace Event Format JSON-object form."""
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {
                "emitted_events": self.emitted_events,
                "dropped_events": self.dropped_events,
            },
        }

    def export(self, path: str) -> int:
        """Write the Chrome-trace JSON to ``path``; returns the event count."""
        trace = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
        return len(trace["traceEvents"])


# ------------------------------------------------------------------- default
# Module-level default (logging-style).  Starts DISABLED so importing the
# engine costs nothing; drivers opt in with set_tracer(Tracer()).
_default = Tracer(capacity=0, enabled=False)


def get_tracer() -> Tracer:
    return _default


def set_tracer(tracer: Tracer | None) -> Tracer:
    """Install ``tracer`` as the process default (None → disabled no-op).

    Returns the installed tracer so drivers can one-line it::

        tr = obs.set_tracer(obs.Tracer())
    """
    global _default
    _default = tracer if tracer is not None else Tracer(capacity=0, enabled=False)
    return _default


def span(name: str, cat: str = "", **kw: Any) -> _Span | _NullSpan:
    """``with obs.span("sweep", "sweep", qid=3): ...`` against the default."""
    t = _default
    if not t.enabled:
        return NULL_SPAN
    return t.span(name, cat, **kw)


def instant(name: str, cat: str = "", **kw: Any) -> None:
    t = _default
    if t.enabled:
        t.instant(name, cat, **kw)


def counter_event(name: str, values: dict[str, float], **kw: Any) -> None:
    t = _default
    if t.enabled:
        t.counter(name, values, **kw)


def validate_chrome_trace(trace: dict[str, Any]) -> list[str]:
    """Structural validation of a Chrome-trace object; returns problem list.

    Used by the CI smoke (and tests) instead of an external JSON-schema
    dependency: checks the object form, required per-event fields, phase
    codes, and numeric timestamps.
    """
    problems: list[str] = []
    if not isinstance(trace, dict):
        return ["top level is not a JSON object"]
    evs = trace.get("traceEvents")
    if not isinstance(evs, list):
        return ["missing or non-list traceEvents"]
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "B", "E", "i", "I", "C", "M"):
            problems.append(f"event {i}: bad ph {ph!r}")
        if not isinstance(ev.get("name"), str):
            problems.append(f"event {i}: missing name")
        if ph != "M" and not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"event {i}: missing ts")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            problems.append(f"event {i}: complete event missing dur")
        if "pid" not in ev or "tid" not in ev:
            problems.append(f"event {i}: missing pid/tid")
    return problems
