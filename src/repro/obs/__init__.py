"""Unified observability layer: structured tracing + typed metrics.

``obs.trace`` is the span/event tracer (Chrome-trace / Perfetto JSON
export); ``obs.metrics`` is the typed Counter/Gauge/Histogram registry
(JSON snapshot + Prometheus text exposition); ``obs.probes`` computes the
DC-specific gauges (diff-store occupancy, Bloom fill / false-positive
rate, governor ladder levels) from engine state.

Both the tracer and the registry have module-level defaults (logging-style)
so the engine/session/serving tiers record without threading handles
through every call site, and a zero-allocation no-op path when disabled so
the hot loop pays nothing by default.
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    set_registry,
)
from repro.obs.trace import (
    Tracer,
    get_tracer,
    set_tracer,
    span,
    instant,
    counter_event,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
    "Tracer",
    "get_tracer",
    "set_tracer",
    "span",
    "instant",
    "counter_event",
]
