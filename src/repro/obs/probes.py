"""DC-specific observability probes.

The gauges no generic APM gives you — they read the engine's differential-
computation state directly:

* per-operator diff-store occupancy (accounted bytes) and dropped-diff
  record counts,
* Bloom fill ratio + estimated false-positive rate — the direct predictor
  of wasted repair work for the paper's probabilistic DroppedVT: a Bloom
  false positive makes the sweep "repair" a vertex that never dropped,
* per-sweep iteration series (frontier/scheduled sizes, from
  ``MaintainStats``),
* governor ladder-level timeline,
* checkpoint/restore byte + latency accounting (published by
  ``runtime.recovery``).

``publish_session_metrics`` pushes the full set into a
:class:`~repro.obs.metrics.MetricsRegistry`; it is the single scrape
surface ``CQPSession.stats()``, ``CQPServer`` and ``cqp_serve
--metrics-out`` share.
"""

from __future__ import annotations

from typing import Any

import numpy as np

from repro.obs.metrics import Counter, MetricsRegistry, get_registry

__all__ = [
    "maintain_stats_dict",
    "bloom_fp_rate",
    "bloom_stats",
    "dropped_diff_counts",
    "publish_session_metrics",
]


def maintain_stats_dict(stats: Any) -> dict:
    """JSON-safe dict view of a ``MaintainStats`` (any engine's).

    Scalar counters become ints; the per-iteration probe vectors become
    lists trimmed to the iterations actually run (bounded by the trace
    depth), so ``session.stats()["last_maintain"]`` reads directly as a
    size-per-iteration series.
    """
    out: dict[str, Any] = {}
    n = None
    for k, v in zip(stats._fields, stats):
        if getattr(v, "ndim", 0):
            if n is None:
                n = min(max(int(out.get("iters_run", 0)), 0), len(np.asarray(v)))
            out[k] = [int(x) for x in np.asarray(v)[:n]]
        else:
            out[k] = int(v)
    return out


def bloom_fp_rate(fill: float, num_hashes: int) -> float:
    """Analytic false-positive rate from the observed fill fraction.

    A membership query probes ``k = num_hashes`` bits; with fraction ``f``
    of the filter set, a never-inserted key passes all probes with
    probability ≈ ``f^k`` (the standard Bloom estimate, using the observed
    fill rather than the insert count — exact under independent probes).
    """
    f = min(max(float(fill), 0.0), 1.0)
    return f ** int(num_hashes)


def _dense_impl(session) -> Any | None:
    """The dense engine's ``DiffIFE`` behind a session, or None."""
    impl = getattr(session, "_impl", None)
    inner = getattr(impl, "impl", None)
    return inner if inner is not None and hasattr(inner, "state") else None


def bloom_stats(session) -> dict[int, dict]:
    """qid → Bloom filter health for sessions on the probabilistic
    DroppedVT representation: fill fraction, analytic FP rate, bit/hash
    geometry.  Empty for det-mode, host and scratch engines."""
    eng = _dense_impl(session)
    if eng is None:
        return {}
    flt = eng.state.drop.flt
    if flt is None:
        return {}
    from repro.core import bloom as bloom_lib

    fill = np.atleast_1d(np.asarray(bloom_lib.fill_fraction(flt)))
    out: dict[int, dict] = {}
    for qid, slot in getattr(session, "_handles", {}).items():
        if slot >= fill.shape[0]:
            continue
        f = float(fill[slot])
        out[qid] = {
            "fill_fraction": f,
            "fp_rate": bloom_fp_rate(f, flt.num_hashes),
            "num_bits": int(flt.num_bits),
            "num_hashes": int(flt.num_hashes),
        }
    return out


def dropped_diff_counts(session) -> dict[int, int]:
    """qid → DroppedVT records currently held in the Det-Drop store (the
    countable representation).  Bloom-mode sessions have no record count —
    their loss signal is :func:`bloom_stats`' FP rate."""
    eng = _dense_impl(session)
    if eng is None:
        return {}
    det = eng.state.drop.det
    if det is None:
        return {}
    counts = np.asarray(det.count)  # [Q, K]
    out: dict[int, int] = {}
    for qid, slot in getattr(session, "_handles", {}).items():
        if slot < counts.shape[0]:
            out[qid] = int(counts[slot].sum())
    return out


def _counter_to(c: Counter, value: float, **labels) -> None:
    """Advance a monotone counter to an absolute value (idempotent scrape)."""
    cur = c.value(**labels)
    if value > cur:
        c.inc(value - cur, **labels)


def publish_session_metrics(
    session, registry: MetricsRegistry | None = None
) -> MetricsRegistry:
    """Scrape one session into the registry; returns the registry.

    Safe to call at any cadence: counters advance monotonically (absolute
    session counters → deltas), gauges overwrite.  This is the bridge that
    makes ``stats()``'s JSON view and the Prometheus exposition read the
    same numbers.
    """
    reg = registry if registry is not None else get_registry()

    # ----- session lifetime counters / point-in-time gauges
    _counter_to(
        reg.counter("cqp_updates_applied_total", "δE updates ingested"),
        session.updates_applied,
    )
    _counter_to(
        reg.counter("cqp_queries_registered_total", "query registrations"),
        session.registered_total,
    )
    _counter_to(
        reg.counter("cqp_queries_deregistered_total", "query deregistrations"),
        session.deregistered_total,
    )
    _counter_to(
        reg.counter("cqp_bytes_freed_total", "bytes released by deregister"),
        session.bytes_freed_total,
    )
    _counter_to(
        reg.counter("cqp_bytes_shed_total", "bytes released by policy sheds"),
        session.bytes_shed_total,
    )
    reg.gauge("cqp_active_queries", "registered queries").set(
        session.num_queries
    )
    reg.gauge(
        "cqp_nbytes", "accounted difference bytes (paper's memory metric)"
    ).set(session.nbytes())

    # ----- per-operator diff-store occupancy (the governor's victim table)
    occ = reg.gauge(
        "cqp_diffstore_bytes", "accounted bytes per (query, operator) store"
    )
    for (qid, op), nbytes in session._nbytes_per_op_map().items():
        occ.set(nbytes, qid=qid, op=op)

    # ----- last sweep (uniform MaintainStats schema across engines)
    ls = session.last_stats
    if ls is not None and hasattr(ls, "_fields"):
        g = reg.gauge(
            "cqp_last_sweep", "last maintenance sweep counters, by field"
        )
        for k, v in zip(ls._fields, ls):
            if not getattr(v, "ndim", 0):
                g.set(int(v), field=k)

    # ----- DroppedVT health
    dropped = dropped_diff_counts(session)
    if dropped:
        g = reg.gauge(
            "cqp_droppedvt_records", "Det-Drop store records per query"
        )
        for qid, n in dropped.items():
            g.set(n, qid=qid)
    bl = bloom_stats(session)
    if bl:
        gf = reg.gauge("cqp_bloom_fill_ratio", "Bloom filter fill fraction")
        gp = reg.gauge(
            "cqp_bloom_fp_rate",
            "estimated Bloom false-positive rate (wasted-repair predictor)",
        )
        for qid, b in bl.items():
            gf.set(b["fill_fraction"], qid=qid)
            gp.set(b["fp_rate"], qid=qid)

    # ----- plan optimizer (repro.planner): rewrites + shared-index health
    planner = getattr(session, "_planner", None)
    if planner is not None:
        snap = planner.snapshot()
        _counter_to(
            reg.counter("cqp_planner_rewrites_total", "plans rewritten"),
            snap["rewrites_total"],
        )
        reg.gauge(
            "cqp_planner_managed_queries", "queries answering through rewrites"
        ).set(len(snap["managed_queries"]))
        lmk = snap.get("landmark")
        if lmk:
            reg.gauge(
                "cqp_landmark_index_nbytes",
                "landmark index bytes held outside engine qids (Gᵀ twin)",
            ).set(lmk["index_nbytes"])
            reg.gauge(
                "cqp_landmark_index_live",
                "1 while the shared landmark index is materialized",
            ).set(1 if lmk["live"] else 0)
            _counter_to(
                reg.counter(
                    "cqp_landmark_sheds_total", "governor index sheds"
                ),
                lmk["sheds_total"],
            )
            _counter_to(
                reg.counter(
                    "cqp_landmark_remats_total", "index re-materializations"
                ),
                lmk["remats_total"],
            )
            _counter_to(
                reg.counter(
                    "cqp_landmark_pruned_work_total",
                    "cumulative live-vertex slots swept by pruned scratch",
                ),
                lmk["pruned_work_total"],
            )

    # ----- governor ladder timeline
    gov = getattr(session, "governor", None)
    if gov is not None:
        lvl = reg.gauge(
            "cqp_governor_level", "policy-ladder rung per (query, operator)"
        )
        for (qid, op), level in gov.op_levels.items():
            lvl.set(level, qid=qid, op=op)
        reg.gauge("cqp_governor_budget_bytes", "memory budget").set(
            gov.budget_bytes
        )
        try:
            reg.gauge(
                "cqp_governor_headroom_bytes", "budget minus accounted bytes"
            ).set(gov.headroom(session))
        except Exception:
            pass
    return reg
