"""Typed metrics registry: Counter / Gauge / Histogram.

One process-wide registry (module-level default, swappable for tests)
replaces the parallel ad-hoc surfaces that grew across the repo —
``core/telemetry.py`` EWMAs, ``serving/metrics.py`` percentile blobs,
per-driver JSON dicts.  Those stay as *consumers*: they publish into the
registry, and ``session.stats()`` / ``server.stats()`` read back through
it, so every exporter (JSON snapshot, Prometheus text, trace counters)
sees one coherent set of series.

Conventions:

- Metric names are ``snake_case`` with a unit suffix (``_bytes``, ``_s``,
  ``_total`` for counters), Prometheus-style.
- Labels are an optional ``dict[str, str|int]``; each distinct label set is
  its own child series.  Label cardinality is the caller's problem — the
  DC probes keep it bounded (qid × operator, ladder rung, shard).
- Histograms use fixed bucket boundaries chosen at registration;
  observations are O(#buckets) with no per-sample allocation.

Thread-safety: mutations take the registry lock (serving records from
executor threads); reads snapshot under the same lock.
"""

from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Any, Iterable

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
]

LabelValue = Any  # coerced to str for export
Labels = tuple[tuple[str, str], ...]


def _labels_key(labels: dict[str, LabelValue] | None) -> Labels:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Metric:
    """Shared child-series bookkeeping for the three metric types."""

    kind = "untyped"

    def __init__(self, name: str, help: str, registry: "MetricsRegistry"):
        self.name = name
        self.help = help
        self._registry = registry
        self._children: dict[Labels, Any] = {}

    def _child(self, labels: dict[str, LabelValue] | None) -> Any:
        key = _labels_key(labels)
        child = self._children.get(key)
        if child is None:
            child = self._new_child()
            self._children[key] = child
        return child

    def _new_child(self) -> Any:  # pragma: no cover - overridden
        raise NotImplementedError

    def series(self) -> Iterable[tuple[Labels, Any]]:
        return list(self._children.items())


class Counter(_Metric):
    """Monotonically increasing count (resets only with the registry)."""

    kind = "counter"

    def _new_child(self) -> list[float]:
        return [0.0]

    def inc(self, amount: float = 1.0, **labels: LabelValue) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment {amount}")
        with self._registry._lock:
            self._child(labels)[0] += amount

    def value(self, **labels: LabelValue) -> float:
        with self._registry._lock:
            return self._children.get(_labels_key(labels), [0.0])[0]


class Gauge(_Metric):
    """Point-in-time value, settable up or down."""

    kind = "gauge"

    def _new_child(self) -> list[float]:
        return [0.0]

    def set(self, value: float, **labels: LabelValue) -> None:
        with self._registry._lock:
            self._child(labels)[0] = float(value)

    def inc(self, amount: float = 1.0, **labels: LabelValue) -> None:
        with self._registry._lock:
            self._child(labels)[0] += amount

    def value(self, **labels: LabelValue) -> float:
        with self._registry._lock:
            return self._children.get(_labels_key(labels), [0.0])[0]


DEFAULT_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


class _HistChild:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, nbuckets: int) -> None:
        self.counts = [0] * (nbuckets + 1)  # +1 = +Inf bucket
        self.sum = 0.0
        self.count = 0


class Histogram(_Metric):
    """Fixed-bucket histogram (cumulative on export, Prometheus-style)."""

    kind = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        registry: "MetricsRegistry",
        buckets: tuple[float, ...] = DEFAULT_BUCKETS,
    ):
        super().__init__(name, help, registry)
        self.buckets = tuple(sorted(float(b) for b in buckets))

    def _new_child(self) -> _HistChild:
        return _HistChild(len(self.buckets))

    def observe(self, value: float, **labels: LabelValue) -> None:
        value = float(value)
        with self._registry._lock:
            child = self._child(labels)
            child.counts[bisect_left(self.buckets, value)] += 1
            child.sum += value
            child.count += 1

    def snapshot(self, **labels: LabelValue) -> dict[str, Any]:
        with self._registry._lock:
            child = self._children.get(_labels_key(labels))
            if child is None:
                return {"count": 0, "sum": 0.0, "buckets": {}}
            cum, out = 0, {}
            for b, c in zip(self.buckets, child.counts):
                cum += c
                out[b] = cum
            return {"count": child.count, "sum": child.sum, "buckets": out}


class MetricsRegistry:
    """Name → metric map with typed registration and two export formats."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._metrics: dict[str, _Metric] = {}

    # ------------------------------------------------------------- register
    def _register(self, cls: type, name: str, help: str, **kw: Any) -> Any:
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if type(existing) is not cls:
                    raise ValueError(
                        f"metric {name!r} already registered as {existing.kind}"
                    )
                return existing
            metric = cls(name, help, self, **kw)
            self._metrics[name] = metric
            return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(
        self, name: str, help: str = "", buckets: tuple[float, ...] = DEFAULT_BUCKETS
    ) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> _Metric | None:
        with self._lock:
            return self._metrics.get(name)

    # --------------------------------------------------------------- export
    def snapshot(self) -> dict[str, Any]:
        """JSON-safe snapshot: {name: {type, help, series: [...]}}."""
        out: dict[str, Any] = {}
        with self._lock:
            for name, m in sorted(self._metrics.items()):
                series = []
                for labels, child in m.series():
                    entry: dict[str, Any] = {"labels": dict(labels)}
                    if isinstance(m, Histogram):
                        cum, buckets = 0, {}
                        for b, c in zip(m.buckets, child.counts):
                            cum += c
                            buckets[repr(b)] = cum
                        entry.update(
                            count=child.count, sum=child.sum, buckets=buckets
                        )
                    else:
                        entry["value"] = child[0]
                    series.append(entry)
                out[name] = {"type": m.kind, "help": m.help, "series": series}
        return out

    def prometheus_text(self) -> str:
        """Prometheus/OpenMetrics text exposition (format 0.0.4)."""
        lines: list[str] = []
        with self._lock:
            for name, m in sorted(self._metrics.items()):
                if m.help:
                    lines.append(f"# HELP {name} {m.help}")
                lines.append(f"# TYPE {name} {m.kind}")
                for labels, child in m.series():
                    lbl = _fmt_labels(labels)
                    if isinstance(m, Histogram):
                        cum = 0
                        for b, c in zip(m.buckets, child.counts):
                            cum += c
                            lines.append(
                                f"{name}_bucket{_fmt_labels(labels, le=repr(b))} {cum}"
                            )
                        lines.append(
                            f"{name}_bucket{_fmt_labels(labels, le='+Inf')} "
                            f"{child.count}"
                        )
                        lines.append(f"{name}_sum{lbl} {child.sum}")
                        lines.append(f"{name}_count{lbl} {child.count}")
                    else:
                        val = _fmt_value(child[0])
                        lines.append(f"{name}_total{lbl} {val}"
                                     if m.kind == "counter" and not name.endswith("_total")
                                     else f"{name}{lbl} {val}")
        return "\n".join(lines) + "\n"

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


def _fmt_value(v: float) -> str:
    """Integral floats render as ints (``3`` not ``3.0``) — counters and
    byte gauges read cleanly in the text exposition."""
    return str(int(v)) if float(v).is_integer() else repr(float(v))


def _fmt_labels(labels: Labels, **extra: str) -> str:
    pairs = [*labels, *sorted(extra.items())]
    if not pairs:
        return ""
    body = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
    return "{" + body + "}"


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


# ------------------------------------------------------------------- default
_default = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _default


def set_registry(registry: MetricsRegistry | None) -> MetricsRegistry:
    """Swap the process default (None → fresh empty registry); returns it."""
    global _default
    _default = registry if registry is not None else MetricsRegistry()
    return _default
