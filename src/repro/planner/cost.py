"""Cost model: when does a rewrite pay?

Signals, in order of preference:

* **per-operator byte accounting** — the session's live
  ``_nbytes_per_query_map`` gives the actual marginal bytes of one more
  differentially-maintained query row (the landmark index costs 2·L such
  rows: L forward fields on G plus L reverse fields on Gᵀ);
* **RecomputeTelemetry EWMAs** — ``iters_run``/``scheduled`` price the
  scratch recompute a rewrite would add (or remove), per ingested update;
* **static plan shape** — ``max_iters``·V bounds the scratch sweep when no
  telemetry has accumulated yet (cold session).

The model is deliberately coarse: rewrite decisions are reversible (the
governor can shed a landmark index it regrets), so the gate only needs to
be directionally right, and every estimate is logged on the planner's
decision trail for inspection.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """One gate decision with the numbers behind it (JSON-able)."""

    pays: bool
    reason: str
    sharers: int = 0  # queries that would share the rewrite's state
    index_rows: int = 0  # diff-maintained rows the shared state costs
    bytes_per_row: float = 0.0  # marginal bytes of one maintained row
    scratch_rate: float = 0.0  # EWMA scratch work per update (pruned away)

    def to_dict(self) -> dict:
        return {
            "pays": self.pays,
            "reason": self.reason,
            "sharers": self.sharers,
            "index_rows": self.index_rows,
            "bytes_per_row": round(self.bytes_per_row, 1),
            "scratch_rate": round(self.scratch_rate, 3),
        }


class CostModel:
    """Decides when a rewrite pays (``optimize="auto"``).

    ``margin`` scales the break-even point: a landmark index on a
    diff-maintaining engine must expect at least ``margin × 2L`` sharing
    queries before its rows cost less than the rows it replaces.
    """

    def __init__(self, *, margin: float = 1.0):
        self.margin = float(margin)

    # ------------------------------------------------------------- signals
    def _telemetry(self, session):
        gov = getattr(session, "_governor", None)
        return None if gov is None else gov.telemetry

    def bytes_per_row(self, session) -> float:
        """Marginal bytes of one maintained query row: the mean over live
        rows' accounted bytes (0.0 when nothing is live yet)."""
        per = session._nbytes_per_query_map()
        vals = [b for b in per.values() if b > 0]
        return float(sum(vals)) / len(vals) if vals else 0.0

    def scratch_rate(self, session) -> float:
        """EWMA scratch work per ingested update: scheduled vertex slots per
        sweep × iterations (falls back to 0.0 on a cold session)."""
        tele = self._telemetry(session)
        if tele is None:
            return 0.0
        return tele.global_ewma("scheduled") * max(
            tele.global_ewma("iters_run"), 1.0
        )

    # ---------------------------------------------------------------- gates
    def landmark(self, plan, session, *, num_landmarks: int, sharers: int) -> CostEstimate:
        """Gate for the landmark hub-cut (paper §6.6).

        * SCRATCH sessions re-run every query per batch — the index prunes
          that work directly (Fig. 9's 43–83% cut), so the rewrite pays for
          any number of sharers.
        * Diff-maintaining engines (dense/host) trade bytes: the rewrite
          replaces ``sharers`` maintained rows with ``2L`` index rows plus
          per-batch pruned-scratch recompute.  It pays once enough queries
          share the index: ``sharers ≥ margin × 2L`` (byte break-even, with
          live per-row byte accounting and the scratch-rate EWMA logged for
          the decision trail).
        """
        index_rows = 2 * int(num_landmarks)
        rate = self.scratch_rate(session)
        if session.engine_kind == "scratch":
            return CostEstimate(
                pays=True,
                reason="scratch engine: pruning cuts per-batch recompute",
                sharers=sharers,
                index_rows=index_rows,
                scratch_rate=rate,
            )
        bpr = self.bytes_per_row(session)
        need = self.margin * index_rows
        if sharers >= need:
            return CostEstimate(
                pays=True,
                reason=f"{sharers} sharers amortize {index_rows} index rows",
                sharers=sharers,
                index_rows=index_rows,
                bytes_per_row=bpr,
                scratch_rate=rate,
            )
        return CostEstimate(
            pays=False,
            reason=(
                f"{sharers} sharers < break-even {need:g} "
                f"(2L rows would cost more than they free)"
            ),
            sharers=sharers,
            index_rows=index_rows,
            bytes_per_row=bpr,
            scratch_rate=rate,
        )
