"""Cost-based plan optimizer (DESIGN.md §16).

Pattern-matches validated operator DAGs (:mod:`repro.core.dataflow`) at
registration time and rewrites the ones where a cheaper execution strategy
pays, recording provenance on the plan.  The flagship pass is the landmark
hub-cut (`landmark_rewrite`, paper §6.6): SPSP plans share one
differentially-maintained landmark-index subplan and answer through
triangle-bound-pruned scratch runs.
"""

from repro.planner.cost import CostEstimate, CostModel
from repro.planner.landmark_rewrite import LandmarkRule
from repro.planner.rules import INDEX_OP, PLANNER_QID, Planner, RewriteRule

__all__ = [
    "CostEstimate",
    "CostModel",
    "INDEX_OP",
    "LandmarkRule",
    "PLANNER_QID",
    "Planner",
    "RewriteRule",
]
