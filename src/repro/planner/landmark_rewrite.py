"""The flagship rewrite: landmark hub-cut for SPSP plans (paper §6.6).

Pattern: a min-plus, source-initialized, join/transform-free plan whose
Aggregate reads one target vertex (``plan.spsp(s, t)``).  Strategy: all
matching queries of a session share ONE landmark-index subplan —

* L forward SSSP fields over G, registered as *internal* queries of the
  host session (ordinary engine rows: operator-addressed difference
  stores, ``nbytes_per_operator`` rows, drop policies, governor ladder);
* L reverse SSSP fields over Gᵀ, held by a nested twin
  :class:`~repro.core.session.CQPSession` on the transposed graph, fed the
  transposed δE of every ingested batch;

— and each query answers through a **pruned-scratch subquery**: a
Bellman-Ford re-run whose expansion is gated by the index's triangle
upper/lower bounds (:func:`repro.core.landmark.triangle_bounds` →
:func:`repro.core.landmark.pruned_scratch_run`).  Answers are exact at the
target (vertices on optimal paths are never pruned).

Governor lever: the whole index is one pseudo-operator row
``(PLANNER_QID, "landmark")`` in the victim table.  Escalation sheds it —
internal rows deregister, the twin session drops, bounds go trivial and the
subquery degrades to plain scratch (answers stay exact, latency rises);
de-escalation re-selects landmarks and re-materializes in-engine.  That is
the "landmark-ize / de-landmark-ize" memory↔latency rung.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import landmark as lm
from repro.core import plan as qp
from repro.planner.cost import CostModel
from repro.planner.rules import INDEX_OP, PLANNER_QID, RewriteRule


class LandmarkRule(RewriteRule):
    """Shared landmark-index runtime for one session's SPSP queries."""

    name = "landmark"
    pseudo_op = INDEX_OP

    def __init__(self, num_landmarks: int = 4):
        self.num_landmarks = int(num_landmarks)
        self.max_iters = 64  # pinned by the first admitted plan
        self.semiring = None  # likewise (matches() restricts to min_plus)
        # shared-index runtime (per session — one rule instance per planner)
        self.landmarks: list[int] = []
        self.fwd_qids: list[int] = []  # internal qids in the host session
        self.rev_session = None  # twin CQPSession over Gᵀ
        self.rev_handles: list = []
        self.shed = False  # governor holds the index de-materialized
        self.queries: dict[int, tuple[int, int]] = {}  # qid → (s, t)
        self._matrix: np.ndarray | None = None  # [Q, V] pruned fields
        self._order: list[int] = []  # matrix row ↔ qid
        self._dirty = False  # pruned fields recompute lazily on read
        # meters (fig9 / probes)
        self.sheds_total = 0
        self.remats_total = 0
        self.pruned_iters_last = 0
        self.pruned_work_total = 0
        self.scratch_seconds = 0.0

    # -------------------------------------------------------------- pattern
    def matches(self, plan: qp.QueryPlan, session) -> bool:
        agg = plan.aggregate
        return (
            plan.nfa is None
            and plan.op_of_kind("transform") is None
            and plan.semiring.name == "min_plus"
            and plan.init.kind == "source"
            and agg is not None
            and agg.agg == "target"
            and agg.vertex is not None
        )

    def pays(self, plan: qp.QueryPlan, session, cost: CostModel):
        est = cost.landmark(
            plan,
            session,
            num_landmarks=self.num_landmarks,
            sharers=len(self.queries) + 1,
        )
        return est.pays, est.to_dict()

    def rewrite(self, plan: qp.QueryPlan, session) -> qp.QueryPlan:
        return plan.with_provenance(
            qp.Provenance(
                rule=self.name,
                original_kind=plan.kind,
                params=(
                    ("source", int(plan.init.source)),
                    ("target", int(plan.aggregate.vertex)),
                    ("num_landmarks", self.num_landmarks),
                ),
            )
        )

    # -------------------------------------------------------------- runtime
    @property
    def _live(self) -> bool:
        return bool(self.fwd_qids)

    def admit(self, session, qid: int, plan: qp.QueryPlan) -> None:
        if not self.queries:
            self.max_iters = int(plan.max_iters)
            self.semiring = plan.semiring
        if not self._live and not self.shed:
            self._build_index(session)
        self.queries[qid] = (int(plan.init.source), int(plan.aggregate.vertex))
        self._dirty = True

    def release(self, session, qid: int) -> int:
        del self.queries[qid]
        if self.queries:
            self._dirty = True
            return 0
        # last sharer gone — the shared index tears down with it
        self._matrix, self._order = None, []
        return self._teardown(session)

    def on_updates(self, session, updates) -> None:
        if not self.queries:
            return
        if self._live:
            self.rev_session.apply_updates(lm.transpose_updates(updates))
        self._dirty = True

    def _ensure_fresh(self, session) -> None:
        """One pruned-scratch sweep serves every read since the last δE
        batch or admission — amortized like the engines' own batching."""
        if self._dirty or (self._matrix is None and self.queries):
            self._refresh(session)
            self._dirty = False

    def answers(self, session, qid: int) -> np.ndarray:
        """The pruned SSSP field [V] — exact at the query's target vertex;
        pruned vertices elsewhere may read +inf."""
        self._ensure_fresh(session)
        return self._matrix[self._order.index(qid)]

    # ------------------------------------------------------- index build/run
    def _build_index(self, session) -> None:
        self.landmarks = lm.select_landmarks(session.graph, self.num_landmarks)
        self.fwd_qids = session._register_internal(
            [qp.sssp(l, max_iters=self.max_iters) for l in self.landmarks]
        )
        self.rev_session = self._twin_session(session)
        self.rev_handles = self.rev_session.register_many(
            [qp.sssp(l, max_iters=self.max_iters) for l in self.landmarks]
        )
        self.shed = False

    def _twin_session(self, session):
        from repro.core.session import CQPSession

        # COO keeps the twin's sweep shape independent of Gᵀ's degree
        # distribution; no mesh — the index is L rows, not worth sharding
        return CQPSession(
            lm.transpose_graph(session.graph),
            engine=session.engine_kind,
            backend="coo",
            batch_capacity=session._kw["batch_capacity"],
            interpret=session._kw["interpret"],
            min_slots=max(self.num_landmarks, 1),
        )

    def _fields(self, session):
        if not self._live:
            return None, None
        fwd = np.stack(
            [
                session._impl.answers_row(session._handles[q])
                for q in self.fwd_qids
            ]
        )
        rev = np.stack(
            [self.rev_session.answers(h) for h in self.rev_handles]
        )
        return fwd, rev

    def _refresh(self, session) -> None:
        """Recompute every owned query's pruned-scratch field."""
        if not self.queries:
            self._matrix, self._order = None, []
            return
        self._order = sorted(self.queries)
        sources = [self.queries[q][0] for q in self._order]
        targets = [self.queries[q][1] for q in self._order]
        fwd, rev = self._fields(session)
        cfg = lm.engine_cfg(
            len(self._order),
            session.graph.num_vertices,
            self.semiring,
            max_iters=self.max_iters,
        )
        t0 = time.perf_counter()
        self._matrix, self.pruned_iters_last, work = lm.pruned_scratch_run(
            cfg, session.graph, sources, targets, fwd, rev
        )
        self.scratch_seconds += time.perf_counter() - t0
        self.pruned_work_total += work

    def _teardown(self, session) -> int:
        freed = 0
        if self.fwd_qids:
            freed += session._deregister_internal(self.fwd_qids)
            self.fwd_qids = []
        if self.rev_session is not None:
            freed += self.rev_session.nbytes()
            self.rev_session = None
            self.rev_handles = []
        self.landmarks = []
        self.shed = False
        if session._governor is not None:
            session._governor.on_deregister(PLANNER_QID)
        return freed

    # ------------------------------------------------------ byte accounting
    def extra_nbytes(self, session) -> int:
        return 0 if self.rev_session is None else self.rev_session.nbytes()

    def pseudo_ops(self, session) -> dict:
        if not self.queries:
            return {}
        # only the twin's bytes: the forward rows are already metered under
        # their internal qids (double counting would inflate the budget sum)
        return {(PLANNER_QID, INDEX_OP): self.extra_nbytes(session)}

    def pseudo_costs(self, session) -> dict:
        if not self.queries:
            return {}
        # shedding the index degrades the subquery to un-pruned scratch, so
        # its "recompute cost" is the pruned work it already pays (monotone)
        return {(PLANNER_QID, INDEX_OP): self.pruned_work_total}

    def set_policy(self, session, cfg) -> int:
        if cfg.enabled() and not self.shed:
            # shed: de-landmark-ize — answers stay exact through un-pruned
            # scratch, the 2·L maintained rows free their bytes
            freed = self._deregister_index(session)
            self.shed = True
            self.sheds_total += 1
            self._dirty = True
            return freed
        if not cfg.enabled() and self.shed:
            # re-materialize: fresh landmark selection (degrees may have
            # drifted), fields recomputed in-engine — still exact
            self._build_index(session)
            self.remats_total += 1
            self._dirty = True
            return 0
        return 0

    def _deregister_index(self, session) -> int:
        freed = 0
        if self.fwd_qids:
            freed += session._deregister_internal(self.fwd_qids)
            self.fwd_qids = []
        if self.rev_session is not None:
            freed += self.rev_session.nbytes()
            self.rev_session = None
            self.rev_handles = []
        self.landmarks = []
        return freed

    # ----------------------------------------------------------- durability
    def snapshot(self, session) -> dict:
        return {
            "queries": len(self.queries),
            "num_landmarks": self.num_landmarks,
            "landmarks": list(self.landmarks),
            "live": self._live,
            "shed": self.shed,
            "index_nbytes": self.extra_nbytes(session),
            "sheds_total": self.sheds_total,
            "remats_total": self.remats_total,
            "pruned_iters_last": self.pruned_iters_last,
            "pruned_work_total": self.pruned_work_total,
            "scratch_seconds": round(self.scratch_seconds, 6),
        }

    def state_dict(self, session) -> tuple[dict, dict]:
        arrays: dict = {}
        meta: dict = {
            "num_landmarks": self.num_landmarks,
            "max_iters": self.max_iters,
            "landmarks": list(self.landmarks),
            "fwd_qids": list(self.fwd_qids),
            "queries": [[int(q), s, t] for q, (s, t) in sorted(self.queries.items())],
            "shed": self.shed,
            "sheds_total": self.sheds_total,
            "remats_total": self.remats_total,
            "pruned_work_total": self.pruned_work_total,
            "rev": None,
        }
        if self.rev_session is not None:
            r_arrays, r_meta = self.rev_session.state_dict()
            arrays.update(
                {f"planner_rev/{k}": v for k, v in r_arrays.items()}
            )
            meta["rev"] = r_meta
        return arrays, meta

    def load_state(self, session, meta: dict, arrays: dict, owned: dict) -> None:
        if not meta:
            return
        self.num_landmarks = int(meta["num_landmarks"])
        self.max_iters = int(meta["max_iters"])
        if owned:
            self.semiring = next(iter(owned.values())).semiring
        else:
            from repro.core import semiring as sr

            self.semiring = sr.min_plus()
        self.landmarks = [int(l) for l in meta["landmarks"]]
        self.fwd_qids = [int(q) for q in meta["fwd_qids"]]
        self.queries = {int(q): (int(s), int(t)) for q, s, t in meta["queries"]}
        self.shed = bool(meta["shed"])
        self.sheds_total = int(meta.get("sheds_total", 0))
        self.remats_total = int(meta.get("remats_total", 0))
        self.pruned_work_total = int(meta.get("pruned_work_total", 0))
        if meta["rev"] is not None:
            from repro.core.session import CQPSession

            # the twin restores unsharded regardless of the host mesh — it
            # is L rows (elastic re-sharding applies to the host engine)
            self.rev_session = CQPSession._from_state(
                {
                    k[len("planner_rev/"):]: v
                    for k, v in arrays.items()
                    if k.startswith("planner_rev/")
                },
                meta["rev"],
                mesh=None,
            )
            self.rev_handles = self.rev_session.handles()
        if self.queries:
            self._dirty = True
