"""Rewrite-rule framework: match → cost gate → rewrite → shared runtime.

A :class:`RewriteRule` is two things at once:

* a **pattern** over validated operator graphs: ``matches(plan, session)``
  inspects the plan's nodes (semiring, init, join/transform presence,
  aggregate shape) and the session family, and ``rewrite(plan, session)``
  produces a new *validated* plan with a :class:`~repro.core.plan.Provenance`
  entry recorded — answers stay attributable to the rule that produced them;
* a **runtime** for the rewritten strategy: rules that share state across
  matching queries (the landmark pass shares one 2·L-field index) own that
  state per session and serve the lifecycle hooks below (`admit`/`release`/
  `on_updates`/`answers`), byte accounting (`extra_nbytes`/`pseudo_ops`) and
  the governor lever (`set_policy`).

The :class:`Planner` orchestrates: at registration it runs each candidate
plan through the rule list, gates the first match through the cost model
(:mod:`repro.planner.cost` — ``optimize="always"`` bypasses the gate), and
routes the query's lifecycle to the owning rule from then on.  Rewritten
queries hold ordinary :class:`~repro.core.session.QueryHandle`s; the session
delegates `answers`/`deregister`/byte accounting for them to the planner.

Governor interaction: rule-owned shared state surfaces in the session's
victim table as pseudo-operator rows keyed ``(PLANNER_QID, op)`` — the
ladder escalates them like any (query, operator) pair, and the resulting
``set_drop_params`` call routes back to ``Planner.set_pseudo_policy`` so
"shed the shared index / re-materialize it" is an online memory↔latency
rung alongside dropping.
"""

from __future__ import annotations

from repro.core import plan as qp
from repro.planner.cost import CostModel

# pseudo qid addressing rule-owned shared state in governor victim tables;
# real qids count up from 0, so the namespaces never collide
PLANNER_QID = -1
# the landmark pass's pseudo-operator id (its ladder rung lives in
# GovernorConfig alongside "join")
INDEX_OP = "landmark"

MODES = ("none", "auto", "always")


class RewriteRule:
    """Base rule: subclasses override the pattern and (if their strategy
    owns runtime state) the lifecycle hooks.  One rule instance serves one
    session — rules may keep per-session state on ``self``."""

    name = "rule"

    # ------------------------------------------------------------- pattern
    def matches(self, plan: qp.QueryPlan, session) -> bool:
        raise NotImplementedError

    def pays(self, plan: qp.QueryPlan, session, cost: CostModel):
        """Cost-gate decision: ``(pays: bool, estimate_dict)``."""
        return True, {}

    def rewrite(self, plan: qp.QueryPlan, session) -> qp.QueryPlan:
        """The transformation: a new validated plan with provenance."""
        raise NotImplementedError

    # ------------------------------------------------------------- runtime
    def admit(self, session, qid: int, plan: qp.QueryPlan) -> None:
        """Take ownership of a rewritten query (build shared state on the
        first admit)."""
        raise NotImplementedError

    def release(self, session, qid: int) -> int:
        """Drop ownership; returns bytes freed (shared state tears down
        with its last owner)."""
        raise NotImplementedError

    def on_updates(self, session, updates) -> None:
        """One δE batch was ingested (engine maintenance already ran)."""

    def answers(self, session, qid: int):
        raise KeyError(qid)

    # ------------------------------------------------------ byte accounting
    def extra_nbytes(self, session) -> int:
        """Bytes owned outside the session engine (e.g. a reverse-graph
        twin session) — folded into ``session.nbytes()``."""
        return 0

    def pseudo_ops(self, session) -> dict:
        """``(PLANNER_QID, op) → bytes`` rows for the governor victim table.
        Count only bytes NOT already metered under engine qids."""
        return {}

    def pseudo_costs(self, session) -> dict:
        """``(PLANNER_QID, op) → cumulative recompute-cost`` counters
        (telemetry EWMAs rank shed victims by bytes/(1+cost_rate))."""
        return {}

    def set_policy(self, session, cfg) -> int:
        """Governor rung for the rule's pseudo-operator: an enabled config
        sheds the shared state (returns bytes freed), a disabled one
        re-materializes it."""
        return 0

    # ---------------------------------------------------------- durability
    def snapshot(self, session) -> dict:
        return {}

    def state_dict(self, session) -> tuple[dict, dict]:
        """(arrays, meta) for the rule's shared state; array keys must be
        namespaced (``planner_<rule>/…``)."""
        return {}, {}

    def load_state(self, session, meta: dict, arrays: dict, owned: dict) -> None:
        """Rebuild shared state at restore; ``owned`` maps the rule's
        restored qids to their plans (engine state is already imported)."""


class Planner:
    """Per-session rewrite orchestrator (`CQPSession(optimize=...)`).

    ``mode``: ``"none"`` registers every plan untouched, ``"auto"`` applies
    a matching rule when its cost estimate pays, ``"always"`` applies every
    match unconditionally.  A per-call ``register(..., optimize=...)``
    overrides the session default.
    """

    def __init__(self, session, mode: str = "auto", *, rules=None, cost=None):
        if mode not in MODES:
            raise ValueError(f"unknown optimize mode {mode!r}; choose {MODES}")
        self.session = session
        self.mode = mode
        self.cost = cost if cost is not None else CostModel()
        if rules is None:
            from repro.planner.landmark_rewrite import LandmarkRule

            rules = [LandmarkRule()]
        self.rules: list[RewriteRule] = list(rules)
        self.owned: dict[int, RewriteRule] = {}  # qid → owning rule
        self.decisions: list[dict] = []  # rewrite decision log (obs/report)
        self.rewrites_total = 0

    # ------------------------------------------------------------ admission
    def consider(self, plan: qp.QueryPlan, mode: str | None = None):
        """The rule that should own this plan, or None to register it
        untouched.  Logs cost-gate rejections."""
        mode = self.mode if mode is None else mode
        if mode == "none":
            return None
        for rule in self.rules:
            if not rule.matches(plan, self.session):
                continue
            if mode == "always":
                return rule
            pays, est = rule.pays(plan, self.session, self.cost)
            if pays:
                return rule
            self.decisions.append(
                {"rule": rule.name, "kind": plan.kind, "applied": False, **est}
            )
        return None

    def admit(self, qid: int, plan: qp.QueryPlan, rule: RewriteRule) -> qp.QueryPlan:
        """Rewrite ``plan`` under ``rule`` and hand it the query's runtime."""
        new_plan = rule.rewrite(plan, self.session)
        rule.admit(self.session, qid, new_plan)
        self.owned[qid] = rule
        self.rewrites_total += 1
        self.decisions.append(
            {"rule": rule.name, "kind": plan.kind, "applied": True, "qid": qid}
        )
        return new_plan

    def owns(self, qid: int) -> bool:
        return qid in self.owned

    def release(self, qid: int) -> int:
        return self.owned.pop(qid).release(self.session, qid)

    # -------------------------------------------------------------- runtime
    def on_updates(self, updates) -> None:
        for rule in self.rules:
            rule.on_updates(self.session, updates)

    def answers(self, qid: int):
        return self.owned[qid].answers(self.session, qid)

    def answers_snapshot(self) -> dict:
        import numpy as np

        return {
            qid: np.array(rule.answers(self.session, qid), copy=True)
            for qid, rule in self.owned.items()
        }

    # ------------------------------------------------------ byte accounting
    def extra_nbytes(self) -> int:
        return sum(r.extra_nbytes(self.session) for r in self.rules)

    def pseudo_ops(self) -> dict:
        out: dict = {}
        for rule in self.rules:
            out.update(rule.pseudo_ops(self.session))
        return out

    def pseudo_costs(self) -> dict:
        out: dict = {}
        for rule in self.rules:
            out.update(rule.pseudo_costs(self.session))
        return out

    def set_pseudo_policy(self, op: str, cfg) -> int:
        """Route a governor ``(PLANNER_QID, op)`` policy rewrite to the rule
        owning that pseudo-operator."""
        for rule in self.rules:
            if op == getattr(rule, "pseudo_op", None):
                return rule.set_policy(self.session, cfg)
        raise KeyError(f"no planner rule owns pseudo-operator {op!r}")

    # ----------------------------------------------------------- durability
    def snapshot(self) -> dict:
        out = {
            "mode": self.mode,
            "rewrites_total": self.rewrites_total,
            "managed_queries": sorted(self.owned),
            "decisions": list(self.decisions[-16:]),
        }
        for rule in self.rules:
            out[rule.name] = rule.snapshot(self.session)
        return out

    def state_dict(self) -> tuple[dict, dict]:
        arrays: dict = {}
        meta: dict = {
            "mode": self.mode,
            "rewrites_total": self.rewrites_total,
            "owned": {str(qid): rule.name for qid, rule in self.owned.items()},
            "rules": {},
        }
        for rule in self.rules:
            r_arrays, r_meta = rule.state_dict(self.session)
            arrays.update(r_arrays)
            meta["rules"][rule.name] = r_meta
        return arrays, meta

    def load_state(self, meta: dict, arrays: dict) -> None:
        self.mode = meta.get("mode", self.mode)
        self.rewrites_total = int(meta.get("rewrites_total", 0))
        by_name = {r.name: r for r in self.rules}
        self.owned = {}
        owned_by_rule: dict[str, dict] = {}
        for qid_s, rule_name in meta.get("owned", {}).items():
            qid = int(qid_s)
            rule = by_name[rule_name]
            self.owned[qid] = rule
            owned_by_rule.setdefault(rule_name, {})[qid] = self.session._plans[qid]
        for rule in self.rules:
            rule.load_state(
                self.session,
                meta.get("rules", {}).get(rule.name, {}),
                arrays,
                owned_by_rule.get(rule.name, {}),
            )
